"""§Roofline summary: reads the dry-run JSONL (dryrun_baseline.jsonl and any
iteration files) and prints the per-cell three-term roofline table."""

import glob
import json
import os

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def load(paths=None):
    paths = paths or sorted(glob.glob(os.path.join(REPO, "dryrun_*.jsonl")))
    rows = []
    for p in paths:
        with open(p) as f:
            for line in f:
                rec = json.loads(line)
                rec["_file"] = os.path.basename(p)
                rows.append(rec)
    return rows


def run():
    rows = load()
    ok = [r for r in rows if r.get("status") == "ok"]
    skipped = [r for r in rows if r.get("status") == "skipped"]
    failed = [r for r in rows if r.get("status") == "failed"]
    return {"rows": rows, "ok": len(ok), "skipped": len(skipped),
            "failed": len(failed), "us_per_call": 0.0}


def main():
    out = run()
    print(f"roofline_report,0,cells_ok={out['ok']};"
          f"skipped={out['skipped']};failed={out['failed']}")
    for r in out["rows"]:
        if r.get("status") != "ok":
            continue
        print(f"#  {r['arch']:>22s} {r['shape']:>11s} {r['mesh']:>7s} "
              f"c={r['compute_s']:.3f}s m={r['memory_s']:.3f}s "
              f"n={r['collective_s']:.3f}s bound={r['bound']:<10s} "
              f"frac={r['roofline_fraction']:.3f} "
              f"mfu≤{r['mfu_bound']:.3f}")
    return out


if __name__ == "__main__":
    main()
