"""Fig. 9: 50-node requests against offerings bucketed by T3 — fulfillment
rises monotonically with the multi-node score (and Fig. 2's single-node-SPS
trap fulfills poorly).

Re-derived through the scenario engine's fulfillment layer: a zero-duration
scenario whose per-offering probes go through ``ClusterSim.probe_fulfillment``
and are therefore recorded to the replayable JSONL trace."""

import numpy as np

from repro.sim import ClusterSim, Scenario

from . import common

REQUEST_NODES = 50


def scenario(max_offerings: int = 2000) -> Scenario:
    return Scenario(name="fig9_t3_fulfillment", duration_hours=0.0,
                    interrupt_model="none", apply_fulfillment=True,
                    catalog_seed=0, max_offerings=max_offerings,
                    market_seed=0)


def run(cat=None):
    cat = cat or common.catalog()
    sim = ClusterSim(scenario(max_offerings=len(cat)), catalog=cat)
    snap = sim.current_snapshot()
    buckets = [(0, 5), (5, 15), (15, 30), (30, 51)]
    rows = []
    for lo, hi in buckets:
        offers = [o for o in snap if lo <= o.t3 < hi][:40]
        ful = [sim.probe_fulfillment(o.offering_id, REQUEST_NODES)
               for o in offers]
        rows.append({"t3_bucket": f"[{lo},{hi})",
                     "mean_fulfilled": float(np.mean(ful)) if ful else 0.0,
                     "n": len(offers)})
    trap = [o for o in snap if o.sps_single == 3 and o.t3 <= 3][:40]
    trap_ful = float(np.mean([sim.probe_fulfillment(o.offering_id,
                                                    REQUEST_NODES)
                              for o in trap])) if trap else 0.0
    means = [r["mean_fulfilled"] for r in rows]
    return {"rows": rows, "monotone": all(a <= b + 1.0 for a, b in
                                          zip(means, means[1:])),
            "single_node_sps3_trap_fulfilled": trap_ful,
            "trace_records": len(sim.recorder.records),
            "us_per_call": 0.0}


def main():
    out = run()
    detail = ";".join(f"{r['t3_bucket']}={r['mean_fulfilled']:.1f}/50"
                      for r in out["rows"])
    print(f"fig9_t3_fulfillment,0,{detail};monotone={out['monotone']};"
          f"sps3_trap={out['single_node_sps3_trap_fulfilled']:.1f}/50")
    return out


if __name__ == "__main__":
    main()
