"""Fig. 5c: small-scale comparison vs SpotKube (NSGA-II, fixed 4 nodes per
selected type) on its original setup: pods 1–50 of (1 vCPU, 1 GiB), candidate
pool restricted to four instance types."""

import numpy as np

from repro.core import (KubePACSProvisioner, Request, e_total, preprocess,
                        restrict, spotkube)

from . import common


def run(cat=None):
    cat = cat or common.catalog()
    types = sorted({o.instance_type for o in cat
                    if o.vcpus <= 8})[:4]          # small types, like t3/c6a/...
    small = restrict(cat, instance_types=types)
    prov = KubePACSProvisioner()
    ratios, wall = [], 0.0
    for pods in (1, 5, 10, 20, 35, 50):
        req = Request(pods=pods, cpu_per_pod=1, mem_per_pod=1)
        items = preprocess(small, req)
        d = prov.provision(req, small)
        wall += d.wall_seconds
        sk = spotkube(items, pods, seed=0, population=32, generations=50)
        e_sk = e_total(sk, pods)
        if e_sk > 0:
            ratios.append(d.metrics["e_total"] / e_sk)
    return {"mean_ratio_vs_spotkube": float(np.mean(ratios)),
            "improvement_pct": 100 * (float(np.mean(ratios)) - 1),
            "us_per_call": wall / 6 * 1e6}


def main():
    out = run()
    print(f"fig5c_spotkube,{out['us_per_call']:.0f},"
          f"kubepacs_over_spotkube=+{out['improvement_pct']:.1f}%")
    return out


if __name__ == "__main__":
    main()
