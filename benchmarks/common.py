"""Shared benchmark fixtures: catalog, the paper's 20 scenarios, timing."""

from __future__ import annotations

import time
from typing import Callable, List, Tuple

from repro.core import Request, generate_catalog

#: §5.1: Cartesian {10,50,100,400,1000} × {(1,2),(2,2),(1,4)} + 5 irregular
SCENARIOS: List[Tuple[int, float, float]] = (
    [(p, c, m) for p in (10, 50, 100, 400, 1000)
     for c, m in ((1, 2), (2, 2), (1, 4))]
    + [(17, 7, 7), (75, 3, 5), (115, 4, 2), (287, 1, 6), (439, 1, 9)]
)


def catalog(seed: int = 0, max_offerings: int = 2000):
    return generate_catalog(seed=seed, max_offerings=max_offerings)


def requests() -> List[Request]:
    return [Request(pods=p, cpu_per_pod=c, mem_per_pod=m)
            for p, c, m in SCENARIOS]


def timed(fn: Callable, *args, repeat: int = 1, **kwargs):
    t0 = time.perf_counter()
    out = None
    for _ in range(repeat):
        out = fn(*args, **kwargs)
    dt = (time.perf_counter() - t0) / repeat
    return out, dt * 1e6          # µs per call
