"""Fig. 8: workload-aware scaling — fraction of specialized instances chosen
under each declared intent (paper: 74.5% network / 84.7% disk / 72.9% both;
general workloads pick specialized types only opportunistically)."""

from repro.core import KubePACSProvisioner, Request

from . import common


def _fractions(pool):
    total = max(pool.total_nodes, 1)
    by = {"general": 0, "network": 0, "disk": 0, "network+disk": 0}
    for it, c in zip(pool.items, pool.counts):
        by[it.offering.specialization] += c
    return {k: v / total for k, v in by.items()}


def run(cat=None, snapshots: int = 8):
    """Aggregate node fractions over several market snapshots (the paper's
    Fig. 8 aggregates a multi-day collection period — a single provisioning
    decision has only 3–6 instance types, too few for a stable fraction)."""
    from repro.core import SpotMarketSimulator
    cat = cat or common.catalog()
    sim = SpotMarketSimulator(cat, seed=0)
    prov = KubePACSProvisioner()
    counts = {name: {"hit": 0, "total": 0} for name in
              ("general", "network", "disk", "disk+network")}
    wall = 0.0
    for _ in range(snapshots):
        snap = sim.snapshot()
        for name, intent in (("general", frozenset()),
                             ("network", frozenset({"network"})),
                             ("disk", frozenset({"disk"})),
                             ("disk+network", frozenset({"disk", "network"}))):
            req = Request(pods=200, cpu_per_pod=2, mem_per_pod=2,
                          workload=intent)
            d = prov.provision(req, snap)
            wall += d.wall_seconds
            for it, c in zip(d.pool.items, d.pool.counts):
                spec = it.offering.specialization
                counts[name]["total"] += c
                if name == "general":
                    counts[name]["hit"] += c if spec == "general" else 0
                elif name == "network":
                    counts[name]["hit"] += c if spec in (
                        "network", "network+disk") else 0
                elif name == "disk":
                    counts[name]["hit"] += c if spec in (
                        "disk", "network+disk") else 0
                else:
                    counts[name]["hit"] += c if spec != "general" else 0
        sim.step(6.0)
    out = {name: v["hit"] / max(v["total"], 1) for name, v in counts.items()}
    out["us_per_call"] = wall / (4 * snapshots) * 1e6
    return out


def main():
    out = run()
    print(f"fig8_preferences,{out['us_per_call']:.0f},"
          f"general_general={out['general']:.1%};"
          f"network_adherence={out['network']:.1%};"
          f"disk_adherence={out['disk']:.1%};"
          f"both_adherence={out['disk+network']:.1%}")
    return out


if __name__ == "__main__":
    main()
