"""Table 2: normalized E_Total of Greedy / fixed α ∈ {0, 0.5, 1} vs GSS.

The three fixed-α solves ride one :func:`solve_ilp_batch` pass on a market
compiled once per scenario and shared with the guarded GSS."""

import numpy as np

from repro.core import (Request, compile_market, e_total, kubepacs_greedy,
                        preprocess, score_counts_batch, solve_ilp_batch)
from repro.core.gss import bracketed_gss

from . import common

FIXED_ALPHAS = (0.0, 0.5, 1.0)


def run(cat=None):
    cat = cat or common.catalog()
    rows = []
    wall = 0.0
    for pods, cpu, mem in [(50, 1, 2), (100, 2, 2), (400, 1, 4)]:
        req = Request(pods=pods, cpu_per_pod=cpu, mem_per_pod=mem)
        items = preprocess(cat, req)
        market = compile_market(items)
        pool, trace = bracketed_gss(items, req.pods, tolerance=0.01,
                                    market=market)
        wall += trace.wall_seconds
        base = e_total(pool, req.pods)
        row = {"ours": 1.0,
               "greedy": e_total(kubepacs_greedy(items, pods), pods) / base}
        batch = solve_ilp_batch(items, pods, FIXED_ALPHAS, market=market)
        fixed_scores = score_counts_batch(items, batch, pods,
                                          arrays=market.metric_arrays)
        for a, score in zip(FIXED_ALPHAS, fixed_scores):
            row[f"alpha_{a}"] = score / base
        rows.append(row)
    mean = {k: float(np.mean([r[k] for r in rows])) for k in rows[0]}
    mean["us_per_call"] = wall / 3 * 1e6
    return mean


def main():
    out = run()
    print(f"table2_fixed_alpha,{out['us_per_call']:.0f},"
          f"greedy={out['greedy']:.4f};alpha0={out['alpha_0.0']:.4f};"
          f"alpha0.5={out['alpha_0.5']:.6f};alpha1={out['alpha_1.0']:.6f};"
          f"ours={out['ours']:.1f}")
    return out


if __name__ == "__main__":
    main()
