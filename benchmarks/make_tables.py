"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from the dry-run JSONL.

    PYTHONPATH=src python -m benchmarks.make_tables dryrun_baseline.jsonl
"""

import json
import sys


def fmt_bytes(b):
    return f"{b/1e9:.1f}"


def load(path):
    return [json.loads(l) for l in open(path)]


def dryrun_table(rows):
    out = ["| arch | shape | mesh | status | compile s | GB/device (args+temp) | collectives |",
           "|---|---|---|---|---|---|---|"]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        if r["status"] == "ok":
            gb = (r["arg_bytes_per_device"]
                  + (r["memory_analysis"].get("temp_bytes") or 0)) / 1e9
            colls = ", ".join(f"{k}:{v/1e9:.1f}GB"
                              for k, v in sorted(r["collectives"].items()))
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | "
                       f"{r['compile_s']:.0f} | {gb:.1f} | {colls or '—'} |")
        elif r["status"] == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                       f"skipped | — | — | {r['reason'][:60]}… |")
        else:
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                       f"**FAILED** | — | — | {r.get('error','')[:60]} |")
    return "\n".join(out)


def roofline_table(rows):
    out = ["| arch | shape | mesh | compute s | memory s | collective s | "
           "bound | roofline frac | MODEL_FLOPs/HLO | MFU bound |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        if r["status"] != "ok":
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r['compute_s']:.3f} | {r['memory_s']:.3f} | "
            f"{r['collective_s']:.3f} | {r['bound']} | "
            f"{r['roofline_fraction']:.3f} | {r['model_flops_ratio']:.3f} | "
            f"{r['mfu_bound']:.4f} |")
    return "\n".join(out)


def main():
    rows = load(sys.argv[1] if len(sys.argv) > 1 else "dryrun_baseline.jsonl")
    print("### Dry-run table\n")
    print(dryrun_table(rows))
    print("\n### Roofline table\n")
    print(roofline_table(rows))


if __name__ == "__main__":
    main()
