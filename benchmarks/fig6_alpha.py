"""Fig. 6: E_Total(α) landscape + GSS exploration across independent runs.

Claims: concave rise-then-step-down; optimizing α beats the α=0 cost-only
baseline (paper: avg +6%, up to +81%).  The 21-point landscape per snapshot
is one :func:`solve_ilp_batch` vectorized DP against a market compiled once
and shared with the guarded GSS (DESIGN.md §8)."""

import numpy as np

from repro.core import (Request, SpotMarketSimulator, compile_market,
                        e_total, preprocess, score_counts_batch,
                        solve_ilp_batch)
from repro.core.gss import bracketed_gss

from . import common


def run(cat=None, runs: int = 8):
    cat = cat or common.catalog()
    sim = SpotMarketSimulator(cat, seed=0)
    req = Request(pods=100, cpu_per_pod=2, mem_per_pod=2)
    gains, peak_alphas, wall = [], [], 0.0
    grid = [i / 20 for i in range(21)]
    curves = []
    for _ in range(runs):
        snap = sim.snapshot()
        items = preprocess(snap, req)
        market = compile_market(items)
        batch = solve_ilp_batch(items, req.pods, grid, market=market)
        curve = score_counts_batch(items, batch, req.pods,
                                   arrays=market.metric_arrays)
        curves.append(curve)
        pool, trace = bracketed_gss(items, req.pods, tolerance=0.01,
                                    market=market)
        wall += trace.wall_seconds
        e_star = e_total(pool, req.pods)
        gains.append(e_star / max(curve[0], 1e-12) - 1)
        peak_alphas.append(pool.alpha)
        sim.step(6.0)
    curves = np.array(curves)
    # step-down check: the mean curve's tail is far below its peak
    mean_curve = curves.mean(axis=0)
    return {
        "avg_gain_over_alpha0_pct": 100 * float(np.mean(gains)),
        "max_gain_over_alpha0_pct": 100 * float(np.max(gains)),
        "mean_peak_alpha": float(np.mean(peak_alphas)),
        "tail_over_peak": float(mean_curve[-1] / mean_curve.max()),
        "us_per_call": wall / runs * 1e6,
    }


def main():
    out = run()
    print(f"fig6_alpha,{out['us_per_call']:.0f},"
          f"gain_over_alpha0_avg=+{out['avg_gain_over_alpha0_pct']:.1f}%;"
          f"max=+{out['max_gain_over_alpha0_pct']:.1f}%;"
          f"peak_alpha={out['mean_peak_alpha']:.3f};"
          f"tail/peak={out['tail_over_peak']:.4f}")
    return out


if __name__ == "__main__":
    main()
