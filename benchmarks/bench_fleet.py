"""Fleet-engine benchmark: replica throughput of ``FleetSim`` vs the
per-seed ``run_replicas`` path, plus decision-memo effectiveness
(DESIGN.md §11).

Emits ``BENCH_fleet.json`` so future PRs have a sweep-throughput
trajectory:

  * ``storm`` — the acceptance scenario (interrupt storm, 250-offering
    catalog, R=256): replicas/second for both paths and their ratio.
    The baseline is measured at a smaller R and reported per-replica —
    the per-seed path is embarrassingly linear in R (one full
    ``ClusterSim`` per seed), so its throughput is R-independent;
  * ``crunch`` — the stochastic pressure scenario, where interruption
    draws genuinely diverge replicas and the memo collapses only
    coinciding (state, demand, exclusion) keys: the honest
    mid-hit-rate data point;
  * ``hetero`` — the heterogeneous-demand scenario (per-replica demand
    jitter, DESIGN.md §12): memo hit rate collapses below 50 %, so the
    fleet pays O(unique ≈ R) solves per tick — the regime the
    collect-then-solve batched tick phase targets.  Recorded as batched
    tick phase ON vs OFF (OFF is the PR 4 per-replica sequential path
    running on the current solver — a *stricter* baseline than PR 4
    itself, whose older solver was slower per cycle);
  * per-scenario ``fleet_stats`` — memo hits/misses/unique solves and
    compiled-market cache hits, so cache effectiveness is asserted from
    counters, not inferred from timing;
  * ``equality_checked`` — the bench re-proves fleet ≡ run_replicas
    decision equality on a small seed set before timing anything (the
    full per-seed proof lives in tests/test_fleet.py).

Usage:
  python -m benchmarks.bench_fleet [--smoke] [--json PATH] [--replicas R]

The checked-in record is refreshed explicitly with ``make bench-fleet``
(→ ``--json BENCH_fleet.json``); the plain run is side-effect-free.
"""

from __future__ import annotations

import argparse
import json
import platform
import time
from typing import List, Optional

import numpy as np

from repro.risk import backtest
from repro.sim import FleetSim, heterogeneous_demand_scenario, run_replicas

#: acceptance bar of the fleet engine (ISSUE 4): ≥20× replica throughput
#: vs per-seed run_replicas at R=256 on the interrupt-storm scenario
TARGET_SPEEDUP = 20.0

#: acceptance bar of the batched tick phase (ISSUE 5): ≥3× replica
#: throughput on the heterogeneous-demand scenario vs the PR 4 sequential
#: tick phase (measured honestly as batched ON vs OFF on today's solver)
TARGET_HETERO_SPEEDUP = 3.0


def _decision_equality(scenario, seeds) -> bool:
    """Fleet and per-seed runner must produce identical decision records."""
    fleet = FleetSim(scenario, seeds, record_traces=True).run()
    per_seed = run_replicas(scenario, seeds)
    for a, b in zip(fleet, per_seed):
        if a.decision_records() != b.decision_records():
            return False
        if a.total_cost != b.total_cost:
            return False
    return True


def _bench_scenario(scenario, fleet_replicas: int, baseline_replicas: int,
                    ) -> dict:
    seeds = list(range(baseline_replicas))
    t0 = time.perf_counter()
    run_replicas(scenario, seeds)
    base_wall = time.perf_counter() - t0
    base_rate = baseline_replicas / base_wall

    # construction (catalog build, market-path scripting, replica setup) is
    # timed too — run_replicas pays for all of that inside its call
    t0 = time.perf_counter()
    fleet = FleetSim(scenario, list(range(fleet_replicas)))
    fleet.run()
    fleet_wall = time.perf_counter() - t0
    fleet_rate = fleet_replicas / fleet_wall

    stats = fleet.stats()
    lookups = stats.get("memo_hits", 0) + stats.get("memo_misses", 0)
    return {
        "scenario": scenario.name,
        "catalog_offerings": scenario.max_offerings,
        "baseline_replicas": baseline_replicas,
        "baseline_ms_per_replica": round(base_wall / baseline_replicas * 1e3,
                                         2),
        "baseline_replicas_per_s": round(base_rate, 2),
        "fleet_replicas": fleet_replicas,
        "fleet_wall_s": round(fleet_wall, 3),
        "fleet_ms_per_replica": round(fleet_wall / fleet_replicas * 1e3, 3),
        "fleet_replicas_per_s": round(fleet_rate, 1),
        "speedup": round(fleet_rate / base_rate, 1),
        "fleet_stats": stats,
        "memo_hit_rate": (round(stats.get("memo_hits", 0) / lookups, 4)
                          if lookups else None),
    }


def _bench_hetero(scenario, fleet_replicas: int) -> dict:
    """Batched tick phase ON vs OFF on the low-memo-hit scenario."""
    seeds = list(range(fleet_replicas))
    t0 = time.perf_counter()
    off = FleetSim(scenario, seeds, batch_decisions=False)
    off.run()
    off_wall = time.perf_counter() - t0
    t0 = time.perf_counter()
    on = FleetSim(scenario, seeds)
    on.run()
    on_wall = time.perf_counter() - t0
    stats = on.stats()
    lookups = stats.get("memo_hits", 0) + stats.get("memo_misses", 0)
    hit_rate = (stats.get("memo_hits", 0) / lookups) if lookups else None
    return {
        "scenario": scenario.name,
        "catalog_offerings": scenario.max_offerings,
        "demand_jitter": scenario.demand_jitter,
        "replicas": fleet_replicas,
        "batched_off_wall_s": round(off_wall, 3),
        "batched_on_wall_s": round(on_wall, 3),
        "batched_off_replicas_per_s": round(fleet_replicas / off_wall, 2),
        "batched_on_replicas_per_s": round(fleet_replicas / on_wall, 2),
        "speedup_on_vs_off": round(off_wall / on_wall, 2),
        "memo_hit_rate": round(hit_rate, 4) if hit_rate is not None else None,
        "fleet_stats": stats,
    }


def run(smoke: bool = False, fleet_replicas: Optional[int] = None,
        json_path: Optional[str] = None) -> dict:
    # smoke still runs a real fleet: R must stay large enough to amortize
    # the (shared) construction cost the speedup target is defined over
    R = fleet_replicas or (128 if smoke else 256)
    base_R = 2 if smoke else 8
    hetero_R = min(R, 32 if smoke else 128)
    tweak = dict(max_offerings=120, duration_hours=24.0) if smoke \
        else dict(max_offerings=250)
    storm = backtest.interrupt_storm_scenario(**tweak)
    crunch = backtest.pressure_crunch_scenario(**tweak)
    hetero = heterogeneous_demand_scenario(**tweak)

    equality = _decision_equality(storm, [0, 1]) \
        and _decision_equality(crunch, [0, 1]) \
        and _decision_equality(hetero, [0, 1])
    if not equality:
        raise AssertionError("fleet ≠ run_replicas decision records — the "
                             "equality contract is broken; refusing to "
                             "report throughput for a divergent engine")

    storm_rec = _bench_scenario(storm, R, base_R)
    crunch_rec = _bench_scenario(crunch, R, base_R)
    hetero_rec = _bench_hetero(hetero, hetero_R)

    out = {
        "benchmark": "bench_fleet",
        "python": platform.python_version(),
        "numpy": np.__version__,
        "machine": platform.machine(),
        "equality_checked": equality,
        "target_speedup": TARGET_SPEEDUP,
        "target_hetero_speedup": TARGET_HETERO_SPEEDUP,
        "storm": storm_rec,
        "crunch": crunch_rec,
        "hetero": hetero_rec,
        "headline": {
            "storm_speedup": storm_rec["speedup"],
            "storm_fleet_replicas_per_s": storm_rec["fleet_replicas_per_s"],
            "crunch_speedup": crunch_rec["speedup"],
            "crunch_memo_hit_rate": crunch_rec["memo_hit_rate"],
            "hetero_memo_hit_rate": hetero_rec["memo_hit_rate"],
            "hetero_batched_speedup": hetero_rec["speedup_on_vs_off"],
            "meets_target": storm_rec["speedup"] >= TARGET_SPEEDUP,
            "hetero_meets_target": (hetero_rec["speedup_on_vs_off"]
                                    >= TARGET_HETERO_SPEEDUP),
        },
    }
    if json_path:
        with open(json_path, "w") as f:
            json.dump(out, f, indent=2)
    return out


def main(argv: Optional[List[str]] = None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small fleet / catalog / horizon (CI)")
    ap.add_argument("--json", default="",
                    help="output record path (e.g. BENCH_fleet.json; "
                         "default: don't write)")
    ap.add_argument("--replicas", type=int, default=None,
                    help="fleet size R (default 256; 128 with --smoke)")
    args = ap.parse_args(argv if argv is not None else [])
    out = run(smoke=args.smoke, fleet_replicas=args.replicas,
              json_path=args.json or None)
    h = out["headline"]
    detail = (f"storm:{h['storm_speedup']}x@R{out['storm']['fleet_replicas']}"
              f";crunch:{h['crunch_speedup']}x"
              f";crunch_hit_rate={h['crunch_memo_hit_rate']}"
              f";hetero:{h['hetero_batched_speedup']}x"
              f"@hit_rate={h['hetero_memo_hit_rate']}"
              f";target>={out['target_speedup']}x:"
              f"{'met' if h['meets_target'] else 'MISSED'}")
    us = round(out["storm"]["fleet_ms_per_replica"] * 1e3)
    print(f"bench_fleet,{us},{detail}")
    return out


if __name__ == "__main__":
    import sys
    main(sys.argv[1:])
