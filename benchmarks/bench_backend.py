"""Decision-plane backend benchmark: cross-decision batched GSS×ILP vs the
PR 1 per-decision NumPy path (DESIGN.md §12).

The scenario is a FleetSim-style tick with ``n_decisions`` *unique* pending
decisions (demands jittered ±15 % around the acceptance market's 5k pods —
the low-memo-hit regime where PR 4's DecisionMemo cannot collapse them):

  * ``pr1_path``        — the PR 1 engine, vendored below verbatim (greedy
    LP prune + min-plus D&C backtracking), driven one bracketed-GSS cycle
    per decision against a shared CompiledMarket: exactly what the fleet
    engine paid per unique decision before this change;
  * ``sequential``      — the new engine (core-bounded prune + one
    improvement-bit DP), still one cycle per decision, numpy backend;
  * ``batched_numpy``   — one :func:`bracketed_gss_many` over all
    decisions (cross-decision stacked prescan + lockstep golden rounds);
  * ``batched_jax``     — the same batched cycle with every DP dispatched
    through the PR 5 per-probe JAX-jitted scan backend;
  * ``fused_jax``       — the PR 6 device-resident plane
    (``make_backend("jax:fused")``): prescan + the whole golden-section
    search as jitted programs, counts read back once and replayed on host
    (DESIGN.md §13).  One-time XLA compile wall is recorded separately
    from steady-state per-decision time (first call minus steady state);
    PR 5's 0.86x number conflated the two.

All walls are interleaved min-of-N (contender order rotated per round) so
thermal throttling on small sustained-load hosts hits every engine alike.
Two tick configs are recorded — the FleetSim-shaped *fleet tick*
(100 items × 1 k pods, where the fused plane wins) and the PR 5
*acceptance market* (250 × 5 k, huge-residual DPs where NumPy still
wins) — plus a catalog-size scaling column (250/1000/4000 offerings).

Selections are asserted identical across every path before timing
(engine-equality is part of the backend contract, tests/test_backend.py).

Usage:
  python -m benchmarks.bench_backend [--smoke] [--json PATH] [--decisions N]

The checked-in record is refreshed with ``make bench-backend``
(→ ``--json BENCH_backend.json``); the plain run is side-effect-free.
"""

from __future__ import annotations

import argparse
import json
import platform
import time
from typing import List, Optional

import numpy as np

from repro.core import (NumpyBackend, Request, compile_market, e_total,
                        generate_catalog, jax_available, make_backend,
                        preprocess)
from repro.core.efficiency import NodePool, score_counts_batch
from repro.core.gss import PHI, GssTrace, bracketed_gss_many

#: ISSUE 5 acceptance bar: ≥5× end-to-end provisioning-cycle speedup over
#: the PR 1 NumPy path at 250 offerings × 5k pods, n_decisions ≥ 32
TARGET_SPEEDUP = 5.0
PRESCAN = 9
TOLERANCE = 0.01

# ---------------------------------------------------------------------------
# The PR 1 engine, vendored verbatim (commit 489a203) as the baseline
# ---------------------------------------------------------------------------

_INF = float("inf")
_DENSE_BUNDLES = 16
_DENSE_TARGET = 512


def _pr1_cover_dp(bpods, bcosts, target):
    dp = np.full(target + 1, _INF)
    dp[0] = 0.0
    for b in range(len(bpods)):
        pb = int(bpods[b])
        cb = bcosts[b]
        if pb > target:
            np.minimum(dp, cb, out=dp)
            continue
        np.minimum(dp[pb:], dp[:-pb] + cb, out=dp[pb:])
        if pb > 1:
            np.minimum(dp[1:pb], dp[0] + cb, out=dp[1:pb])
    return dp


def _pr1_lp_prune(bpods, bcosts, target):
    B = len(bpods)
    if B == 0 or target <= 0:
        return np.ones(B, dtype=bool)
    rate = bcosts / bpods
    order = np.argsort(rate, kind="stable")
    p_sorted = bpods[order].astype(np.float64)
    c_sorted = bcosts[order]
    cum_p = np.cumsum(p_sorted)
    cum_c = np.cumsum(c_sorted)
    if cum_p[-1] < target:
        return np.ones(B, dtype=bool)
    k_ub = int(np.searchsorted(cum_p, target))
    ub = float(cum_c[k_ub])
    resid = np.maximum(target - bpods, 0).astype(np.float64)
    k = np.searchsorted(cum_p, resid)
    prev_p = np.where(k > 0, cum_p[np.maximum(k - 1, 0)], 0.0)
    prev_c = np.where(k > 0, cum_c[np.maximum(k - 1, 0)], 0.0)
    lp = prev_c + (resid - prev_p) * (c_sorted[k] / p_sorted[k])
    lp[resid <= 0] = 0.0
    return bcosts + lp <= ub * (1.0 + 1e-12) + 1e-9


def _pr1_dense_backtrack(bpods, bcosts, target):
    B = len(bpods)
    take = np.zeros(B, dtype=bool)
    if target <= 0:
        return take
    dp = np.full(target + 1, _INF)
    dp[0] = 0.0
    history = np.empty((B + 1, target + 1))
    history[0] = dp
    for b in range(B):
        pb = int(bpods[b])
        cut = min(pb, target + 1)
        shifted = np.empty(target + 1)
        shifted[:cut] = dp[0]
        if cut <= target:
            shifted[cut:] = dp[: target + 1 - pb]
        dp = np.minimum(dp, shifted + bcosts[b])
        history[b + 1] = dp
    j = target
    for b in range(B - 1, -1, -1):
        if j == 0:
            break
        if history[b + 1][j] < history[b][j] - 1e-12:
            take[b] = True
            j = max(0, j - int(bpods[b]))
    return take


def _pr1_dc_backtrack(bpods, bcosts, target):
    B = len(bpods)
    if target <= 0:
        return np.zeros(B, dtype=bool)
    if B <= _DENSE_BUNDLES or target <= _DENSE_TARGET:
        return _pr1_dense_backtrack(bpods, bcosts, target)
    mid = B // 2
    dp_l = _pr1_cover_dp(bpods[:mid], bcosts[:mid], target)
    dp_r = _pr1_cover_dp(bpods[mid:], bcosts[mid:], target)
    tot = dp_l + dp_r[::-1]
    j1 = int(np.argmin(tot))
    take = np.empty(B, dtype=bool)
    take[:mid] = _pr1_dc_backtrack(bpods[:mid], bcosts[:mid], j1)
    take[mid:] = _pr1_dc_backtrack(bpods[mid:], bcosts[mid:], target - j1)
    return take


def _pr1_solve(market, req_pods, alpha):
    coef = market.coefficients(np.array([alpha]))[0]
    n = market.n
    active = market.structural
    counts = np.zeros(n, dtype=np.int64)
    neg = (coef < 0) & active
    counts[neg] = market.bound[neg]
    covered = int(np.sum(market.pods[neg] * market.bound[neg]))
    residual = max(0, req_pods - covered)
    if residual == 0:
        return list(map(int, counts))
    in_dp = active & ~neg
    if int(np.sum(market.pods[in_dp] * market.bound[in_dp])) < residual:
        return None
    bidx = np.flatnonzero(in_dp[market.b_item])
    bpods = market.b_pods[bidx]
    bcosts = coef[market.b_item[bidx]] * market.b_copies[bidx]
    keep = _pr1_lp_prune(bpods, bcosts, residual)
    kept_idx = np.flatnonzero(keep)
    take = np.zeros(len(bpods), dtype=bool)
    take[kept_idx] = _pr1_dc_backtrack(bpods[kept_idx], bcosts[kept_idx],
                                       residual)
    taken = bidx[take]
    np.add.at(counts, market.b_item[taken], market.b_copies[taken])
    return list(map(int, counts))


def pr1_bracketed_gss(items, req_pods, market):
    """The PR 1 guarded cycle: 9-α prescan + golden refinement, every
    solve through the vendored PR 1 solver (one decision at a time)."""
    grid = [i / (PRESCAN - 1) for i in range(PRESCAN)]
    counts_list = [_pr1_solve(market, req_pods, a) for a in grid]
    scores = score_counts_batch(items, counts_list, req_pods,
                                none_score=float("-inf"),
                                arrays=market.metric_arrays)
    pools = [None if c is None else NodePool(items=list(items), counts=c)
             for c in counts_list]
    best_pool, best_f, best_idx = None, float("-inf"), 0
    for gi, (alpha, score, pool) in enumerate(zip(grid, scores, pools)):
        if pool is not None:
            pool.alpha = alpha
        if score > best_f:
            best_pool, best_f, best_idx = pool, score, gi
    a = grid[max(0, best_idx - 1)]
    b = grid[min(len(grid) - 1, best_idx + 1)]

    cache = {}

    def evaluate(alpha):
        key = round(alpha, 12)
        if key in cache:
            return cache[key]
        counts = _pr1_solve(market, req_pods, alpha)
        if counts is None:
            out = (None, float("-inf"))
        else:
            pool = NodePool(items=list(items), counts=counts, alpha=alpha)
            out = (pool, e_total(pool, req_pods))
        cache[key] = out
        return out

    x1 = b - PHI * (b - a)
    x2 = a + PHI * (b - a)
    pool1, f1 = evaluate(x1)
    pool2, f2 = evaluate(x2)
    g_pool, g_f = (pool1, f1) if f1 >= f2 else (pool2, f2)
    while (b - a) > TOLERANCE:
        if f1 >= f2:
            b = x2
            x2, f2, pool2 = x1, f1, pool1
            x1 = b - PHI * (b - a)
            pool1, f1 = evaluate(x1)
            if f1 > g_f:
                g_pool, g_f = pool1, f1
        else:
            a = x1
            x1, f1, pool1 = x2, f2, pool2
            x2 = a + PHI * (b - a)
            pool2, f2 = evaluate(x2)
            if f2 > g_f:
                g_pool, g_f = pool2, f2
    if g_pool is not None:
        g_pool = g_pool.nonzero()
    inner_f = e_total(g_pool, req_pods) if g_pool is not None \
        else float("-inf")
    if best_pool is not None and best_f > inner_f:
        return best_pool.nonzero()
    return g_pool


# ---------------------------------------------------------------------------
# Benchmark driver
# ---------------------------------------------------------------------------

def _jittered_demands(base: int, n: int, jitter: float = 0.15,
                      seed: int = 0) -> List[int]:
    rng = np.random.default_rng(seed)
    return [int(base * (1 + jitter * (2 * rng.random() - 1)))
            for _ in range(n)]


def _interleaved(fns: dict, repeat: int) -> dict:
    """min-of-N wall time per contender, contenders interleaved and the
    visit order rotated each round.  On small sustained-load hosts the
    clock throttles mid-benchmark; back-to-back ``best_of`` loops hand one
    contender the fast thermal window and another the slow one, while
    interleaving exposes every contender to the same drift."""
    names = list(fns)
    best = {k: float("inf") for k in names}
    for r in range(repeat):
        order = names[r % len(names):] + names[: r % len(names)]
        for k in order:
            t0 = time.perf_counter()
            fns[k]()
            best[k] = min(best[k], time.perf_counter() - t0)
    return best


def _pools_equal(a_pools, b_pools) -> bool:
    return all(
        (a is None) == (b is None) and (a is None or
                                        a.as_dict() == b.as_dict())
        for a, b in zip(a_pools, b_pools))


def bench_tick(n_items: int, base_pods: int, n_dec: int, *,
               repeat: int = 3, include_pr1: bool = True,
               max_offerings: int = 2000) -> dict:
    """One fleet-tick benchmark config: ``n_dec`` jittered decisions over a
    shared market, every engine timed interleaved, jitted engines warmed
    first with the one-time compile wall recorded separately (first call
    minus steady state — the PR 5 record conflated the two)."""
    cat = generate_catalog(seed=0, max_offerings=max_offerings)
    items = preprocess(cat, Request(pods=base_pods, cpu_per_pod=2,
                                    mem_per_pod=2))[:n_items]
    market = compile_market(items)
    demands = _jittered_demands(base_pods, n_dec)
    numpy_be = NumpyBackend()
    fake = lambda: 0.0                                     # noqa: E731

    def batched_pools_of(backend):
        return [p for p, _t in bracketed_gss_many(
            items, demands, tolerance=TOLERANCE, market=market,
            timer=fake, backend=backend)]

    def sequential_cycle(backend):
        for r in demands:
            bracketed_gss_many(items, [r], tolerance=TOLERANCE,
                               market=market, timer=fake, backend=backend)

    def batched_cycle(backend):
        bracketed_gss_many(items, demands, tolerance=TOLERANCE,
                           market=market, timer=fake, backend=backend)

    # equality gate before any timing: all engines select identical pools
    batched_pools = batched_pools_of(numpy_be)
    equality = True
    if include_pr1:
        pr1_pools = [pr1_bracketed_gss(items, r, market) for r in demands]
        equality = _pools_equal(pr1_pools, batched_pools)
        if not equality:
            raise AssertionError(
                "backend engines disagree with the PR 1 selections — "
                "refusing to time a divergent decision plane")

    fns = {"sequential_numpy": lambda: sequential_cycle(numpy_be),
           "batched_numpy": lambda: batched_cycle(numpy_be)}
    if include_pr1:
        fns["pr1"] = lambda: [pr1_bracketed_gss(items, r, market)
                              for r in demands]

    rec: dict = {"n_items": len(items), "base_pods": base_pods,
                 "n_decisions": n_dec, "demand_jitter": 0.15,
                 "equality_checked": equality,
                 "jax_available": jax_available()}
    first_calls: dict = {}
    fused_be = None
    if jax_available():
        jax_be = make_backend("jax")
        fused_be = make_backend("jax:fused")
        # first call = XLA trace + compile + one steady run; steady state
        # is measured interleaved below, compile ≈ first − steady
        for name, be in (("batched_jax", jax_be), ("fused_jax", fused_be)):
            t0 = time.perf_counter()
            pools = batched_pools_of(be)
            first_calls[name] = time.perf_counter() - t0
            rec[f"{name}_selections_equal_numpy"] = _pools_equal(
                batched_pools, pools)
        fns["batched_jax"] = lambda: batched_cycle(jax_be)
        fns["fused_jax"] = lambda: batched_cycle(fused_be)

    best = _interleaved(fns, repeat)
    for name, wall in best.items():
        rec[f"{name}_wall_s"] = round(wall, 3)
        rec[f"{name}_ms_per_decision"] = round(wall / n_dec * 1e3, 2)
    for name, first in first_calls.items():
        rec[f"{name}_first_call_s"] = round(first, 3)
        rec[f"{name}_compile_s"] = round(max(0.0, first - best[name]), 3)
    if include_pr1:
        rec["speedups_vs_pr1"] = {
            k: round(best["pr1"] / v, 2) for k, v in best.items()
            if k != "pr1"}
    if "fused_jax" in best:
        rec["fused_vs_batched_numpy"] = round(
            best["batched_numpy"] / best["fused_jax"], 2)
        info = fused_be.device_cache_info()
        rec["fused_fallback_solves"] = info.get("fallback_solves", 0)
    return rec


def bench_scaling(offering_sizes=(250, 1000, 4000), *, base_pods: int = 1000,
                  n_dec: int = 8, repeat: int = 2) -> List[dict]:
    """Catalog-size scaling column: batched NumPy vs fused steady state at
    growing offering counts, demand held at ``base_pods``.  The fused
    engine's per-probe sort is Θ(B log B) on every golden round while the
    host engine sorts once per objective and prunes early, so the crossover
    (fused faster below ~250 offerings, slower above) is the honest record,
    not a tuning failure."""
    rows: List[dict] = []
    fake = lambda: 0.0                                     # noqa: E731
    numpy_be = NumpyBackend()
    for size in offering_sizes:
        cat = generate_catalog(seed=0, max_offerings=size)
        items = preprocess(cat, Request(pods=base_pods, cpu_per_pod=2,
                                        mem_per_pod=2))
        market = compile_market(items)
        demands = _jittered_demands(base_pods, n_dec)

        def batched(backend):
            return [p for p, _t in bracketed_gss_many(
                items, demands, tolerance=TOLERANCE, market=market,
                timer=fake, backend=backend)]

        row: dict = {"offerings": size, "n_items": len(items),
                     "base_pods": base_pods, "n_decisions": n_dec}
        fns = {"batched_numpy": lambda: batched(numpy_be)}
        if jax_available():
            fused_be = make_backend("jax:fused")
            t0 = time.perf_counter()
            fused_pools = batched(fused_be)
            first = time.perf_counter() - t0
            row["selections_equal_numpy"] = _pools_equal(
                batched(numpy_be), fused_pools)
            fns["fused_jax"] = lambda: batched(fused_be)
        best = _interleaved(fns, repeat)
        row["batched_numpy_wall_s"] = round(best["batched_numpy"], 3)
        if "fused_jax" in best:
            row["fused_steady_wall_s"] = round(best["fused_jax"], 3)
            row["fused_compile_s"] = round(
                max(0.0, first - best["fused_jax"]), 3)
            row["fused_vs_batched_numpy"] = round(
                best["batched_numpy"] / best["fused_jax"], 2)
        rows.append(row)
    return rows


def run(smoke: bool = False, n_decisions: Optional[int] = None,
        json_path: Optional[str] = None, repeat: int = 3,
        scaling: Optional[bool] = None) -> dict:
    """Full benchmark record.

    Two tick configs are measured: the *fleet tick* (100 items × 1 k pods —
    the FleetSim steady-state shape, where per-decision host overhead
    dominates and the fused engine wins) and, outside smoke, the PR 5
    *acceptance market* (250 items × 5 k pods — huge-residual cover DPs
    where NumPy's in-cache loops still win; kept as the honest continuity
    row).  ``--smoke`` runs only the fleet tick with fewer decisions.
    """
    n_dec = n_decisions or (8 if smoke else 32)
    configs = {"fleet_tick": bench_tick(100, 1000, n_dec, repeat=repeat)}
    if not smoke:
        configs["acceptance_market"] = bench_tick(250, 5000, n_dec,
                                                  repeat=repeat)
    if scaling is None:
        scaling = not smoke
    out = {
        "benchmark": "bench_backend",
        "python": platform.python_version(),
        "numpy": np.__version__,
        "machine": platform.machine(),
        "target_speedup": TARGET_SPEEDUP,
        "configs": configs,
        "scaling": bench_scaling() if scaling else [],
    }
    tick = configs["fleet_tick"]
    out["headline"] = {
        "fused_vs_batched_numpy_fleet_tick":
            tick.get("fused_vs_batched_numpy"),
        "fused_steady_faster_than_numpy":
            (tick.get("fused_vs_batched_numpy") or 0.0) > 1.0,
        "fused_vs_per_dispatch_jax": (
            round(tick["batched_jax_wall_s"] / tick["fused_jax_wall_s"], 2)
            if "fused_jax_wall_s" in tick else None),
        "pr1_meets_target": any(
            isinstance(v, float) and v >= TARGET_SPEEDUP
            for cfg in configs.values()
            for v in cfg.get("speedups_vs_pr1", {}).values()),
    }
    if json_path:
        with open(json_path, "w") as f:
            json.dump(out, f, indent=2)
    return out


def main(argv: Optional[List[str]] = None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fleet-tick config only, few decisions (CI)")
    ap.add_argument("--json", default="",
                    help="output record path (e.g. BENCH_backend.json; "
                         "default: don't write)")
    ap.add_argument("--decisions", type=int, default=None,
                    help="pending decisions per tick (default 32; 8 smoke)")
    ap.add_argument("--repeat", type=int, default=3,
                    help="interleaved timing rounds per config")
    ap.add_argument("--scaling", action="store_true", default=None,
                    help="force the catalog-size scaling column (default: "
                         "on unless --smoke)")
    args = ap.parse_args(argv if argv is not None else [])
    out = run(smoke=args.smoke, n_decisions=args.decisions,
              json_path=args.json or None, repeat=args.repeat,
              scaling=args.scaling)
    tick = out["configs"]["fleet_tick"]
    h = out["headline"]
    detail = (f"numpy:{tick['batched_numpy_wall_s']}s"
              f";fused:{tick.get('fused_jax_wall_s', 'n/a')}s"
              f"(compile:{tick.get('fused_jax_compile_s', 'n/a')}s)"
              f";fused_vs_numpy:{h['fused_vs_batched_numpy_fleet_tick']}x"
              f";fused_vs_jax:{h['fused_vs_per_dispatch_jax']}x")
    us = round(tick["batched_numpy_wall_s"] / tick["n_decisions"] * 1e6)
    print(f"bench_backend,{us},{detail}")
    return out


if __name__ == "__main__":
    import sys
    main(sys.argv[1:])
