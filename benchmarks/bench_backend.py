"""Decision-plane backend benchmark: cross-decision batched GSS×ILP vs the
PR 1 per-decision NumPy path (DESIGN.md §12).

The scenario is a FleetSim-style tick with ``n_decisions`` *unique* pending
decisions (demands jittered ±15 % around the acceptance market's 5k pods —
the low-memo-hit regime where PR 4's DecisionMemo cannot collapse them):

  * ``pr1_path``        — the PR 1 engine, vendored below verbatim (greedy
    LP prune + min-plus D&C backtracking), driven one bracketed-GSS cycle
    per decision against a shared CompiledMarket: exactly what the fleet
    engine paid per unique decision before this change;
  * ``sequential``      — the new engine (core-bounded prune + one
    improvement-bit DP), still one cycle per decision, numpy backend;
  * ``batched_numpy``   — one :func:`bracketed_gss_many` over all
    decisions (cross-decision stacked prescan + lockstep golden rounds);
  * ``batched_jax``     — the same batched cycle with every DP dispatched
    through the JAX-jitted scan backend (absent → recorded as skipped).
    NOTE: on small CPU hosts XLA's scan under-runs the ragged host path —
    the honest number is recorded either way; the jax backend's value is
    the accelerator path (one fused dispatch per phase), not CPU wins.

Selections are asserted identical across every path before timing
(engine-equality is part of the backend contract, tests/test_backend.py).

Usage:
  python -m benchmarks.bench_backend [--smoke] [--json PATH] [--decisions N]

The checked-in record is refreshed with ``make bench-backend``
(→ ``--json BENCH_backend.json``); the plain run is side-effect-free.
"""

from __future__ import annotations

import argparse
import json
import platform
import time
from typing import List, Optional

import numpy as np

from repro.core import (NumpyBackend, Request, compile_market, e_total,
                        generate_catalog, jax_available, make_backend,
                        preprocess)
from repro.core.efficiency import NodePool, score_counts_batch
from repro.core.gss import PHI, GssTrace, bracketed_gss_many

#: ISSUE 5 acceptance bar: ≥5× end-to-end provisioning-cycle speedup over
#: the PR 1 NumPy path at 250 offerings × 5k pods, n_decisions ≥ 32
TARGET_SPEEDUP = 5.0
PRESCAN = 9
TOLERANCE = 0.01

# ---------------------------------------------------------------------------
# The PR 1 engine, vendored verbatim (commit 489a203) as the baseline
# ---------------------------------------------------------------------------

_INF = float("inf")
_DENSE_BUNDLES = 16
_DENSE_TARGET = 512


def _pr1_cover_dp(bpods, bcosts, target):
    dp = np.full(target + 1, _INF)
    dp[0] = 0.0
    for b in range(len(bpods)):
        pb = int(bpods[b])
        cb = bcosts[b]
        if pb > target:
            np.minimum(dp, cb, out=dp)
            continue
        np.minimum(dp[pb:], dp[:-pb] + cb, out=dp[pb:])
        if pb > 1:
            np.minimum(dp[1:pb], dp[0] + cb, out=dp[1:pb])
    return dp


def _pr1_lp_prune(bpods, bcosts, target):
    B = len(bpods)
    if B == 0 or target <= 0:
        return np.ones(B, dtype=bool)
    rate = bcosts / bpods
    order = np.argsort(rate, kind="stable")
    p_sorted = bpods[order].astype(np.float64)
    c_sorted = bcosts[order]
    cum_p = np.cumsum(p_sorted)
    cum_c = np.cumsum(c_sorted)
    if cum_p[-1] < target:
        return np.ones(B, dtype=bool)
    k_ub = int(np.searchsorted(cum_p, target))
    ub = float(cum_c[k_ub])
    resid = np.maximum(target - bpods, 0).astype(np.float64)
    k = np.searchsorted(cum_p, resid)
    prev_p = np.where(k > 0, cum_p[np.maximum(k - 1, 0)], 0.0)
    prev_c = np.where(k > 0, cum_c[np.maximum(k - 1, 0)], 0.0)
    lp = prev_c + (resid - prev_p) * (c_sorted[k] / p_sorted[k])
    lp[resid <= 0] = 0.0
    return bcosts + lp <= ub * (1.0 + 1e-12) + 1e-9


def _pr1_dense_backtrack(bpods, bcosts, target):
    B = len(bpods)
    take = np.zeros(B, dtype=bool)
    if target <= 0:
        return take
    dp = np.full(target + 1, _INF)
    dp[0] = 0.0
    history = np.empty((B + 1, target + 1))
    history[0] = dp
    for b in range(B):
        pb = int(bpods[b])
        cut = min(pb, target + 1)
        shifted = np.empty(target + 1)
        shifted[:cut] = dp[0]
        if cut <= target:
            shifted[cut:] = dp[: target + 1 - pb]
        dp = np.minimum(dp, shifted + bcosts[b])
        history[b + 1] = dp
    j = target
    for b in range(B - 1, -1, -1):
        if j == 0:
            break
        if history[b + 1][j] < history[b][j] - 1e-12:
            take[b] = True
            j = max(0, j - int(bpods[b]))
    return take


def _pr1_dc_backtrack(bpods, bcosts, target):
    B = len(bpods)
    if target <= 0:
        return np.zeros(B, dtype=bool)
    if B <= _DENSE_BUNDLES or target <= _DENSE_TARGET:
        return _pr1_dense_backtrack(bpods, bcosts, target)
    mid = B // 2
    dp_l = _pr1_cover_dp(bpods[:mid], bcosts[:mid], target)
    dp_r = _pr1_cover_dp(bpods[mid:], bcosts[mid:], target)
    tot = dp_l + dp_r[::-1]
    j1 = int(np.argmin(tot))
    take = np.empty(B, dtype=bool)
    take[:mid] = _pr1_dc_backtrack(bpods[:mid], bcosts[:mid], j1)
    take[mid:] = _pr1_dc_backtrack(bpods[mid:], bcosts[mid:], target - j1)
    return take


def _pr1_solve(market, req_pods, alpha):
    coef = market.coefficients(np.array([alpha]))[0]
    n = market.n
    active = market.structural
    counts = np.zeros(n, dtype=np.int64)
    neg = (coef < 0) & active
    counts[neg] = market.bound[neg]
    covered = int(np.sum(market.pods[neg] * market.bound[neg]))
    residual = max(0, req_pods - covered)
    if residual == 0:
        return list(map(int, counts))
    in_dp = active & ~neg
    if int(np.sum(market.pods[in_dp] * market.bound[in_dp])) < residual:
        return None
    bidx = np.flatnonzero(in_dp[market.b_item])
    bpods = market.b_pods[bidx]
    bcosts = coef[market.b_item[bidx]] * market.b_copies[bidx]
    keep = _pr1_lp_prune(bpods, bcosts, residual)
    kept_idx = np.flatnonzero(keep)
    take = np.zeros(len(bpods), dtype=bool)
    take[kept_idx] = _pr1_dc_backtrack(bpods[kept_idx], bcosts[kept_idx],
                                       residual)
    taken = bidx[take]
    np.add.at(counts, market.b_item[taken], market.b_copies[taken])
    return list(map(int, counts))


def pr1_bracketed_gss(items, req_pods, market):
    """The PR 1 guarded cycle: 9-α prescan + golden refinement, every
    solve through the vendored PR 1 solver (one decision at a time)."""
    grid = [i / (PRESCAN - 1) for i in range(PRESCAN)]
    counts_list = [_pr1_solve(market, req_pods, a) for a in grid]
    scores = score_counts_batch(items, counts_list, req_pods,
                                none_score=float("-inf"),
                                arrays=market.metric_arrays)
    pools = [None if c is None else NodePool(items=list(items), counts=c)
             for c in counts_list]
    best_pool, best_f, best_idx = None, float("-inf"), 0
    for gi, (alpha, score, pool) in enumerate(zip(grid, scores, pools)):
        if pool is not None:
            pool.alpha = alpha
        if score > best_f:
            best_pool, best_f, best_idx = pool, score, gi
    a = grid[max(0, best_idx - 1)]
    b = grid[min(len(grid) - 1, best_idx + 1)]

    cache = {}

    def evaluate(alpha):
        key = round(alpha, 12)
        if key in cache:
            return cache[key]
        counts = _pr1_solve(market, req_pods, alpha)
        if counts is None:
            out = (None, float("-inf"))
        else:
            pool = NodePool(items=list(items), counts=counts, alpha=alpha)
            out = (pool, e_total(pool, req_pods))
        cache[key] = out
        return out

    x1 = b - PHI * (b - a)
    x2 = a + PHI * (b - a)
    pool1, f1 = evaluate(x1)
    pool2, f2 = evaluate(x2)
    g_pool, g_f = (pool1, f1) if f1 >= f2 else (pool2, f2)
    while (b - a) > TOLERANCE:
        if f1 >= f2:
            b = x2
            x2, f2, pool2 = x1, f1, pool1
            x1 = b - PHI * (b - a)
            pool1, f1 = evaluate(x1)
            if f1 > g_f:
                g_pool, g_f = pool1, f1
        else:
            a = x1
            x1, f1, pool1 = x2, f2, pool2
            x2 = a + PHI * (b - a)
            pool2, f2 = evaluate(x2)
            if f2 > g_f:
                g_pool, g_f = pool2, f2
    if g_pool is not None:
        g_pool = g_pool.nonzero()
    inner_f = e_total(g_pool, req_pods) if g_pool is not None \
        else float("-inf")
    if best_pool is not None and best_f > inner_f:
        return best_pool.nonzero()
    return g_pool


# ---------------------------------------------------------------------------
# Benchmark driver
# ---------------------------------------------------------------------------

def _jittered_demands(base: int, n: int, jitter: float = 0.15,
                      seed: int = 0) -> List[int]:
    rng = np.random.default_rng(seed)
    return [int(base * (1 + jitter * (2 * rng.random() - 1)))
            for _ in range(n)]


def _best_of(fn, repeat: int) -> float:
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run(smoke: bool = False, n_decisions: Optional[int] = None,
        json_path: Optional[str] = None, repeat: int = 2) -> dict:
    n_items, base_pods = (100, 1000) if smoke else (250, 5000)
    n_dec = n_decisions or (8 if smoke else 32)
    cat = generate_catalog(seed=0, max_offerings=2000)
    items = preprocess(cat, Request(pods=base_pods, cpu_per_pod=2,
                                    mem_per_pod=2))[:n_items]
    market = compile_market(items)
    demands = _jittered_demands(base_pods, n_dec)
    numpy_be = NumpyBackend()
    fake = lambda: 0.0                                     # noqa: E731

    # equality gate before any timing: all engines select identical pools
    pr1_pools = [pr1_bracketed_gss(items, r, market) for r in demands]
    seq = bracketed_gss_many(items, demands, tolerance=TOLERANCE,
                             market=market, timer=fake, backend=numpy_be)
    batched_pools = [p for p, _t in seq]
    equality = all(
        (a is None) == (b is None) and (a is None or (
            a.as_dict() == b.as_dict()))
        for a, b in zip(pr1_pools, batched_pools))
    if not equality:
        raise AssertionError("backend engines disagree with the PR 1 "
                             "selections — refusing to time a divergent "
                             "decision plane")

    def sequential_cycle(backend):
        for r in demands:
            bracketed_gss_many(items, [r], tolerance=TOLERANCE,
                               market=market, timer=fake, backend=backend)

    def batched_cycle(backend):
        bracketed_gss_many(items, demands, tolerance=TOLERANCE,
                           market=market, timer=fake, backend=backend)

    t_pr1 = _best_of(lambda: [pr1_bracketed_gss(items, r, market)
                              for r in demands], repeat)
    t_seq = _best_of(lambda: sequential_cycle(numpy_be), repeat)
    t_batch_np = _best_of(lambda: batched_cycle(numpy_be), repeat)

    jax_rec: dict = {"available": jax_available()}
    if jax_rec["available"]:
        jax_be = make_backend("jax")
        jax_pools = [p for p, _t in bracketed_gss_many(
            items, demands, tolerance=TOLERANCE, market=market, timer=fake,
            backend=jax_be)]
        jax_rec["selections_equal_numpy"] = all(
            (a is None) == (b is None) and (a is None or
                                            a.as_dict() == b.as_dict())
            for a, b in zip(batched_pools, jax_pools))
        jax_rec["batched_wall_s"] = round(
            _best_of(lambda: batched_cycle(jax_be), repeat), 3)
        jax_rec["speedup_vs_pr1"] = round(t_pr1 / jax_rec["batched_wall_s"],
                                          2)

    # homogeneous fleet tick for reference: identical decisions collapse to
    # one unique solve (the regime PR 4's memo already handled)
    t_homog = _best_of(lambda: bracketed_gss_many(
        items, [base_pods] * n_dec, tolerance=TOLERANCE, market=market,
        timer=fake, backend=numpy_be), repeat)

    speedups = {
        "sequential_numpy": round(t_pr1 / t_seq, 2),
        "batched_numpy": round(t_pr1 / t_batch_np, 2),
        "batched_jax": jax_rec.get("speedup_vs_pr1"),
        "batched_numpy_homogeneous": round(t_pr1 / t_homog, 2),
    }
    best_name = max((k for k, v in speedups.items() if isinstance(v, float)
                     and k != "batched_numpy_homogeneous"),
                    key=lambda k: speedups[k])
    out = {
        "benchmark": "bench_backend",
        "python": platform.python_version(),
        "numpy": np.__version__,
        "machine": platform.machine(),
        "n_items": n_items,
        "base_pods": base_pods,
        "n_decisions": n_dec,
        "demand_jitter": 0.15,
        "equality_checked": equality,
        "target_speedup": TARGET_SPEEDUP,
        "pr1_wall_s": round(t_pr1, 3),
        "pr1_ms_per_decision": round(t_pr1 / n_dec * 1e3, 1),
        "sequential_numpy_wall_s": round(t_seq, 3),
        "batched_numpy_wall_s": round(t_batch_np, 3),
        "batched_numpy_homogeneous_wall_s": round(t_homog, 3),
        "jax": jax_rec,
        "speedups_vs_pr1": speedups,
        "headline": {
            "best_config": best_name,
            "best_speedup": speedups[best_name],
            "meets_target": speedups[best_name] >= TARGET_SPEEDUP,
            "jax_meets_target": (jax_rec.get("speedup_vs_pr1") or 0.0)
            >= TARGET_SPEEDUP,
        },
    }
    if json_path:
        with open(json_path, "w") as f:
            json.dump(out, f, indent=2)
    return out


def main(argv: Optional[List[str]] = None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small market / few decisions (CI)")
    ap.add_argument("--json", default="",
                    help="output record path (e.g. BENCH_backend.json; "
                         "default: don't write)")
    ap.add_argument("--decisions", type=int, default=None,
                    help="pending decisions per tick (default 32; 8 smoke)")
    args = ap.parse_args(argv if argv is not None else [])
    out = run(smoke=args.smoke, n_decisions=args.decisions,
              json_path=args.json or None)
    s = out["speedups_vs_pr1"]
    h = out["headline"]
    detail = (f"pr1:{out['pr1_ms_per_decision']}ms/dec"
              f";seq:{s['sequential_numpy']}x"
              f";batched:{s['batched_numpy']}x"
              f";jax:{s['batched_jax']}x"
              f";homog:{s['batched_numpy_homogeneous']}x"
              f";target>={out['target_speedup']}x:"
              f"{'met' if h['meets_target'] else 'MISSED'}"
              f"(best={h['best_config']})")
    us = round(out["batched_numpy_wall_s"] / out["n_decisions"] * 1e6)
    print(f"bench_backend,{us},{detail}")
    return out


if __name__ == "__main__":
    import sys
    main(sys.argv[1:])
