"""One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.

Usage:
  python -m benchmarks.run                    # full sweep
  python -m benchmarks.run --only fig7_tolerance
  python -m benchmarks.run --only bench_solver --json out.json

``--json`` additionally folds every checked-in ``BENCH_*.json`` micro-
benchmark record into a ``trajectory`` key, so the repo's whole perf
history (solver, risk, fleet, …) is machine-readable from one file.
"""
import argparse
import glob
import json
import os
import sys
import traceback

from . import (bench_backend, bench_chaos, bench_fleet, bench_region,
               bench_risk, bench_scale,
               bench_serve, bench_solver, elastic_training, fig5_sota,
               fig5c_spotkube,
               fig6_alpha, fig6b_cross_provider, fig7_tolerance,
               fig8_preferences, fig9_t3_fulfillment, fig12_interrupts,
               roofline_report, table2_fixed_alpha, table3_perf_dollar)

ALL = [
    ("fig5_sota", fig5_sota),
    ("fig5c_spotkube", fig5c_spotkube),
    ("fig6_alpha", fig6_alpha),
    ("fig6b_cross_provider", fig6b_cross_provider),
    ("table2_fixed_alpha", table2_fixed_alpha),
    ("fig7_tolerance", fig7_tolerance),
    ("fig8_preferences", fig8_preferences),
    ("fig9_t3_fulfillment", fig9_t3_fulfillment),
    ("fig12_interrupts", fig12_interrupts),
    ("table3_perf_dollar", table3_perf_dollar),
    ("bench_solver", bench_solver),
    ("bench_backend", bench_backend),
    ("bench_scale", bench_scale),
    ("bench_risk", bench_risk),
    ("bench_fleet", bench_fleet),
    ("bench_serve", bench_serve),
    ("bench_chaos", bench_chaos),
    ("bench_region", bench_region),
    ("elastic_training", elastic_training),
    ("roofline_report", roofline_report),
]


def bench_trajectory(root: str = ".") -> dict:
    """Consolidate every checked-in ``BENCH_*.json`` record: the benchmark
    modules each refresh their own file (``make bench-solver`` /
    ``bench-risk`` / ``bench-fleet``); this view stitches the perf history
    together, keyed by file stem, with each record's ``headline`` (when the
    writer provides one) surfaced next to the full record."""
    trajectory = {}
    for path in sorted(glob.glob(os.path.join(root, "BENCH_*.json"))):
        name = os.path.splitext(os.path.basename(path))[0]
        try:
            with open(path) as f:
                record = json.load(f)
        except (OSError, json.JSONDecodeError) as exc:
            trajectory[name] = {"status": "unreadable", "error": str(exc)}
            continue
        trajectory[name] = {"record": record}
        if isinstance(record, dict) and "headline" in record:
            trajectory[name]["headline"] = record["headline"]
    return trajectory


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", default=None, metavar="NAME",
                    help="run a single figure/table/microbenchmark by name")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write every driver's returned record to PATH")
    args = ap.parse_args(argv)

    selected = ALL
    if args.only is not None:
        selected = [(n, m) for n, m in ALL if n == args.only]
        if not selected:
            names = ", ".join(n for n, _ in ALL)
            print(f"unknown benchmark {args.only!r}; choose from: {names}",
                  file=sys.stderr)
            sys.exit(2)

    print("name,us_per_call,derived")
    records = {}
    failures = 0
    for name, mod in selected:
        try:
            records[name] = mod.main()
        except Exception:                      # noqa: BLE001
            failures += 1
            traceback.print_exc()
            print(f"{name},0,FAILED")
            records[name] = {"status": "failed"}
    if args.json:
        records["trajectory"] = bench_trajectory()
        with open(args.json, "w") as f:
            json.dump(records, f, indent=2, default=str)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
