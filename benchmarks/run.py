# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
import sys
import traceback

from . import (elastic_training, fig5_sota, fig5c_spotkube, fig6_alpha,
               fig6b_cross_provider, fig7_tolerance, fig8_preferences,
               fig9_t3_fulfillment, fig12_interrupts, roofline_report,
               table2_fixed_alpha, table3_perf_dollar)

ALL = [
    ("fig5_sota", fig5_sota),
    ("fig5c_spotkube", fig5c_spotkube),
    ("fig6_alpha", fig6_alpha),
    ("fig6b_cross_provider", fig6b_cross_provider),
    ("table2_fixed_alpha", table2_fixed_alpha),
    ("fig7_tolerance", fig7_tolerance),
    ("fig8_preferences", fig8_preferences),
    ("fig9_t3_fulfillment", fig9_t3_fulfillment),
    ("fig12_interrupts", fig12_interrupts),
    ("table3_perf_dollar", table3_perf_dollar),
    ("elastic_training", elastic_training),
    ("roofline_report", roofline_report),
]


def main() -> None:
    print("name,us_per_call,derived")
    failures = 0
    for name, mod in ALL:
        try:
            mod.main()
        except Exception:                      # noqa: BLE001
            failures += 1
            traceback.print_exc()
            print(f"{name},0,FAILED")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
