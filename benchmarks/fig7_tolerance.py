"""Fig. 7: GSS tolerance ε vs solver latency/ILP-solve count vs E_Total.

Claims: iterations ≈ 5n+1 for ε=10⁻ⁿ (Eq. 7); ε=0.01 is the sweet spot.

Re-derived as scenarios: one zero-duration scenario per ε running the
unguarded Algorithm-1 GSS (the paper's configuration) through the engine;
each row is the scenario's initial ProvisioningDecision."""

from repro.core import expected_iterations
from repro.sim import ClusterSim, Scenario

from . import common


def scenario(eps: float, max_offerings: int = 2000) -> Scenario:
    return Scenario(
        name=f"fig7_eps{eps:g}", duration_hours=0.0,
        pods=100, cpu_per_pod=2, mem_per_pod=2,
        policy="kubepacs_unguarded", tolerance=eps,
        interrupt_model="none", catalog_seed=0, max_offerings=max_offerings,
    )


def run(cat=None):
    cat = cat or common.catalog()
    rows = []
    for n in (1, 2, 3, 4):
        eps = 10.0 ** -n
        res = ClusterSim(scenario(eps, max_offerings=len(cat)),
                         catalog=cat).run()
        _, decision = res.decisions[0]
        rows.append({
            "eps": eps,
            "ilp_solves": decision.trace.ilp_solves,
            "predicted_iters": expected_iterations(eps),
            "wall_s": decision.trace.wall_seconds,
            "e_total": decision.metrics["e_total"],
        })
    base = max(r["e_total"] for r in rows)
    for r in rows:
        r["e_ratio"] = r["e_total"] / base
    return {"rows": rows, "us_per_call": rows[1]["wall_s"] * 1e6}


def main():
    out = run()
    detail = ";".join(
        f"eps={r['eps']:g}:solves={r['ilp_solves']}"
        f"(pred~{r['predicted_iters']})"
        f",t={r['wall_s']:.2f}s,E={r['e_ratio']:.4f}" for r in out["rows"])
    print(f"fig7_tolerance,{out['us_per_call']:.0f},{detail}")
    return out


if __name__ == "__main__":
    main()
