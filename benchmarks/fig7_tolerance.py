"""Fig. 7: GSS tolerance ε vs solver latency/ILP-solve count vs E_Total.

Claims: iterations ≈ 5n+1 for ε=10⁻ⁿ (Eq. 7); ε=0.01 is the sweet spot."""

import numpy as np

from repro.core import Request, e_total, expected_iterations, preprocess
from repro.core.gss import golden_section_search

from . import common


def run(cat=None):
    cat = cat or common.catalog()
    req = Request(pods=100, cpu_per_pod=2, mem_per_pod=2)
    items = preprocess(cat, req)
    rows = []
    for n in (1, 2, 3, 4):
        eps = 10.0 ** -n
        pool, trace = golden_section_search(items, req.pods, tolerance=eps)
        rows.append({
            "eps": eps,
            "ilp_solves": trace.ilp_solves,
            "predicted_iters": expected_iterations(eps),
            "wall_s": trace.wall_seconds,
            "e_total": e_total(pool, req.pods) if pool else 0.0,
        })
    base = max(r["e_total"] for r in rows)
    for r in rows:
        r["e_ratio"] = r["e_total"] / base
    return {"rows": rows, "us_per_call": rows[1]["wall_s"] * 1e6}


def main():
    out = run()
    detail = ";".join(
        f"eps={r['eps']:g}:solves={r['ilp_solves']}"
        f"(pred~{r['predicted_iters']})"
        f",t={r['wall_s']:.2f}s,E={r['e_ratio']:.4f}" for r in out["rows"])
    print(f"fig7_tolerance,{out['us_per_call']:.0f},{detail}")
    return out


if __name__ == "__main__":
    main()
