"""Framework benchmark: elastic spot training under injected interruptions —
steps/s, recovery latency, and provisioning overhead of the integrated
KubePACS control plane (the paper's <2 s / <194 MB overhead claim, §5.3).

The trainer is driven by the scenario engine's event stream: the market,
interruption sampling, and the replayable trace all live in a
``ClusterSim`` wrapped around the seeded market."""

import tempfile
import time

import numpy as np

from repro.configs import get_config
from repro.core import Request, SpotMarketSimulator, generate_catalog
from repro.runtime import ElasticConfig, ElasticSpotTrainer
from repro.sim import ClusterSim


def run():
    cfg = get_config("internlm2-1.8b", smoke=True)
    market = SpotMarketSimulator(generate_catalog(seed=3, max_offerings=400),
                                 seed=3)
    cluster = ClusterSim.from_market(market, interrupt_model="pressure",
                                     interrupt_seed=3,
                                     name="elastic_training")
    req = Request(pods=40, cpu_per_pod=2, mem_per_pod=4)
    with tempfile.TemporaryDirectory() as d:
        tr = ElasticSpotTrainer(cfg, req, cluster, d, ElasticConfig(
            total_steps=40, ckpt_every=10, market_check_every=4,
            market_hours_per_check=6.0, batch_rows=8, seq_len=128))
        t0 = time.perf_counter()
        out = tr.run()
        wall = time.perf_counter() - t0
    prov_wall = [e["detail"].get("wall_s", 0.0) for e in out["events"]
                 if e["event"] == "provision"]
    return {
        "steps_per_s": out["steps"] / wall,
        "loss_drop": float(np.mean(out["losses"][:5])
                           - np.mean(out["losses"][-5:])),
        "interrupts_handled": out["interrupts_handled"],
        "mean_recovery_s": float(np.mean(out["recovery_times"]))
        if out["recovery_times"] else 0.0,
        "provision_wall_s": float(np.mean(prov_wall)) if prov_wall else 0.0,
        "trace_records": out["trace_records"],
        "us_per_call": wall / out["steps"] * 1e6,
    }


def main():
    out = run()
    print(f"elastic_training,{out['us_per_call']:.0f},"
          f"steps_per_s={out['steps_per_s']:.2f};"
          f"loss_drop={out['loss_drop']:.3f};"
          f"interrupts={out['interrupts_handled']};"
          f"recovery={out['mean_recovery_s']:.2f}s;"
          f"provision={out['provision_wall_s']:.2f}s;"
          f"trace={out['trace_records']}rec")
    return out


if __name__ == "__main__":
    main()
