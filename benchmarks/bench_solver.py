"""Solver microbenchmark: full guarded-GSS provisioning cycles, engine vs
the seed history-matrix solver, across market sizes and demands.

Emits ``BENCH_solver.json`` so future PRs have a performance trajectory:

  * ``cycle_us_engine``      — batched prescan + compiled-market GSS
                               (compilation included, as in `provision()`)
  * ``cycle_us_engine_warm`` — compiled market reused (§4.1 re-optimization)
  * ``cycle_us_reference``   — seed solver driven per-α (the pre-engine path)
  * single-solve peak allocations (tracemalloc) at a residual-heavy α, plus
    the analytic size of the seed's O(bundles × residual) history matrix.

Usage:
  python -m benchmarks.bench_solver [--smoke] [--json PATH] [--repeat N]

The checked-in baseline is refreshed explicitly with
``make bench-solver`` (→ ``--json BENCH_solver.json``); the plain CSV
sweep (including via ``benchmarks.run``) is side-effect-free.
"""

from __future__ import annotations

import argparse
import json
import platform
import tracemalloc
from typing import List, Optional

import numpy as np

from repro.core import (Request, compile_market, e_total, generate_catalog,
                        preprocess, solve_ilp, solve_ilp_reference)
from repro.core.gss import bracketed_gss

from . import common

#: (n_items, req_pods) — the 250/5000 case is the acceptance configuration
#: (≥200 candidate items, ≥5k requested pods, prescan 9, tolerance 0.01).
CASES = [(100, 1000), (250, 5000), (500, 10000)]
SMOKE_CASES = [(100, 1000)]
PRESCAN = 9
TOLERANCE = 0.01


def _items_for(n_items: int, req_pods: int):
    cat = common.catalog(seed=0, max_offerings=2000)
    req = Request(pods=req_pods, cpu_per_pod=2, mem_per_pod=2)
    items = preprocess(cat, req)[:n_items]
    return items


def _residual_heavy_alpha(items, req_pods: int) -> float:
    """A low α whose residual covering DP dominates (worst-case memory)."""
    for alpha in (0.02, 0.05, 0.0):
        _, stats = solve_ilp(items, req_pods, alpha, return_stats=True)
        if stats.residual_demand > 0:
            return alpha
    return 0.0


def _time_cycles(fn, repeat: int) -> float:
    import time
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def bench_case(n_items: int, req_pods: int, repeat: int = 3) -> dict:
    items = _items_for(n_items, req_pods)
    market = compile_market(items)

    engine_pool, engine_trace = bracketed_gss(
        items, req_pods, tolerance=TOLERANCE, prescan=PRESCAN)
    ref_pool, ref_trace = bracketed_gss(
        items, req_pods, tolerance=TOLERANCE, prescan=PRESCAN,
        solver=solve_ilp_reference)

    cycle_engine = _time_cycles(
        lambda: bracketed_gss(items, req_pods, tolerance=TOLERANCE,
                              prescan=PRESCAN), repeat)
    cycle_engine_warm = _time_cycles(
        lambda: bracketed_gss(items, req_pods, tolerance=TOLERANCE,
                              prescan=PRESCAN, market=market), repeat)
    cycle_reference = _time_cycles(
        lambda: bracketed_gss(items, req_pods, tolerance=TOLERANCE,
                              prescan=PRESCAN, solver=solve_ilp_reference),
        repeat)    # same repeat count as the engine: best-of-N vs best-of-N

    alpha = _residual_heavy_alpha(items, req_pods)
    _, stats = solve_ilp(items, req_pods, alpha, market=market,
                         return_stats=True)

    tracemalloc.start()
    solve_ilp(items, req_pods, alpha, market=market)
    _, peak_engine = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    tracemalloc.start()
    solve_ilp_reference(items, req_pods, alpha)
    _, peak_reference = tracemalloc.get_traced_memory()
    tracemalloc.stop()

    history_bytes = market.n_bundles * (stats.residual_demand + 1) * 8
    return {
        "n_items": len(items),
        "req_pods": req_pods,
        "prescan": PRESCAN,
        "tolerance": TOLERANCE,
        "n_bundles": market.n_bundles,
        "residual_demand": stats.residual_demand,
        "ilp_solves_per_cycle": engine_trace.ilp_solves,
        "cycle_us_engine": round(cycle_engine),
        "cycle_us_engine_warm": round(cycle_engine_warm),
        "cycle_us_reference": round(cycle_reference),
        "speedup_full_cycle": round(cycle_reference / cycle_engine, 2),
        "speedup_warm_cycle": round(cycle_reference / cycle_engine_warm, 2),
        "e_total_engine": e_total(engine_pool, req_pods),
        "e_total_reference": e_total(ref_pool, req_pods),
        "solve_peak_bytes_engine": peak_engine,
        "solve_peak_bytes_reference": peak_reference,
        "seed_history_matrix_bytes": history_bytes,
    }


def run(smoke: bool = False, repeat: int = 3,
        json_path: Optional[str] = None) -> dict:
    cases = [bench_case(n, r, repeat)
             for n, r in (SMOKE_CASES if smoke else CASES)]
    out = {
        "benchmark": "bench_solver",
        "python": platform.python_version(),
        "numpy": np.__version__,
        "machine": platform.machine(),
        "cases": cases,
        "us_per_call": cases[-1]["cycle_us_engine"],
        "min_speedup_full_cycle": min(c["speedup_full_cycle"] for c in cases),
    }
    if json_path:
        with open(json_path, "w") as f:
            json.dump(out, f, indent=2)
    return out


def main(argv: Optional[List[str]] = None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="single small case (CI)")
    ap.add_argument("--json", default="",
                    help="output record path (e.g. BENCH_solver.json; "
                         "default: don't write)")
    ap.add_argument("--repeat", type=int, default=3)
    args = ap.parse_args(argv if argv is not None else [])
    out = run(smoke=args.smoke, repeat=args.repeat,
              json_path=args.json or None)
    detail = ";".join(
        f"{c['n_items']}x{c['req_pods']}:"
        f"{c['cycle_us_engine']}us(vs{c['cycle_us_reference']}us,"
        f"{c['speedup_full_cycle']}x,mem{c['solve_peak_bytes_engine']//1024}K"
        f"vs{c['solve_peak_bytes_reference']//1024}K)"
        for c in out["cases"])
    print(f"bench_solver,{out['us_per_call']:.0f},{detail}")
    return out


if __name__ == "__main__":
    import sys
    main(sys.argv[1:])
