"""Risk-subsystem benchmark: backtest kubepacs_risk vs kubepacs across the
standard stress scenarios and score forecast calibration (DESIGN.md §10).

Emits ``BENCH_risk.json`` so future PRs have a risk-performance trajectory:

  * per-scenario ``summary`` — seed-mean perf-per-dollar net of
    interruption losses, interrupted nodes, lost perf, cost — for the
    static policy and the risk policy, plus their net-ppd ratio;
  * ``calibration`` — Brier score and predicted-vs-realized interrupted
    node counts of the hazard forecast replayed over a recorded
    interrupt-storm trace;
  * ``decision_overhead_us`` — wall time of one risk-adjusted provisioning
    cycle vs the static cycle at the storm's market size (the adjustment
    is O(n) on top of the unchanged solver stack).

Usage:
  python -m benchmarks.bench_risk [--smoke] [--json PATH] [--repeat N]

The checked-in record is refreshed explicitly with ``make bench-risk``
(→ ``--json BENCH_risk.json``); the plain run is side-effect-free.
"""

from __future__ import annotations

import argparse
import json
import platform
import time
from typing import List, Optional

import numpy as np

from repro.core import Request, compile_market, preprocess
from repro.risk import backtest
from repro.sim import ClusterSim, make_policy

RISK_POLICY = "kubepacs_risk:12"
POLICIES = ("kubepacs", RISK_POLICY)


def _scenarios(smoke: bool):
    # smoke: shorter horizon + smaller catalog, single seed
    tweak = dict(duration_hours=24.0, max_offerings=120) if smoke else {}
    return [
        (backtest.interrupt_storm_scenario(**tweak), (0,)),
        (backtest.price_shock_scenario(**tweak), (0,)),
        (backtest.pressure_crunch_scenario(**tweak),
         (0,) if smoke else (0, 1, 2)),
    ]


def _decision_overhead(scenario, repeat: int) -> dict:
    """One provisioning cycle, static vs risk-adjusted, best-of-N."""
    catalog = scenario.build_catalog()
    request = Request(pods=scenario.pods, cpu_per_pod=scenario.cpu_per_pod,
                      mem_per_pod=scenario.mem_per_pod)
    items = preprocess(catalog, request)
    market = compile_market(items)
    out = {}
    for spec in POLICIES:
        policy = make_policy(spec)
        policy.bind(catalog)
        best = float("inf")
        for _ in range(repeat):
            t0 = time.perf_counter()
            policy.provision(request, catalog, 0.0,
                             precompiled=(items, market))
            best = min(best, time.perf_counter() - t0)
        out[spec] = round(best * 1e6)
    out["overhead_ratio"] = round(out[RISK_POLICY] / out["kubepacs"], 3)
    return out


def run(smoke: bool = False, repeat: int = 3,
        json_path: Optional[str] = None) -> dict:
    scenarios = _scenarios(smoke)
    results = {}
    for scenario, seeds in scenarios:
        comp = backtest.compare_policies(scenario, policies=POLICIES,
                                         seeds=seeds)
        comp["net_ppd_ratio"] = round(
            comp["summary"][RISK_POLICY]["mean_net_ppd"]
            / comp["summary"]["kubepacs"]["mean_net_ppd"], 4)
        results[scenario.name] = comp

    storm, storm_seeds = scenarios[0]
    trace = ClusterSim(storm).run().records
    calibration = backtest.calibration_report(trace)

    out = {
        "benchmark": "bench_risk",
        "python": platform.python_version(),
        "numpy": np.__version__,
        "machine": platform.machine(),
        "risk_policy": RISK_POLICY,
        "scenarios": results,
        "calibration": calibration,
        "decision_overhead_us": _decision_overhead(storm, repeat),
        "storm_net_ppd_ratio":
            results[storm.name]["net_ppd_ratio"],
    }
    if json_path:
        with open(json_path, "w") as f:
            json.dump(out, f, indent=2)
    return out


def main(argv: Optional[List[str]] = None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="short horizons / small catalogs (CI)")
    ap.add_argument("--json", default="",
                    help="output record path (e.g. BENCH_risk.json; "
                         "default: don't write)")
    ap.add_argument("--repeat", type=int, default=3)
    args = ap.parse_args(argv if argv is not None else [])
    out = run(smoke=args.smoke, repeat=args.repeat,
              json_path=args.json or None)
    detail = ";".join(
        f"{name}:risk/static={rec['net_ppd_ratio']}"
        for name, rec in out["scenarios"].items())
    detail += (f";brier={out['calibration']['brier']:.3f}"
               f";overhead={out['decision_overhead_us']['overhead_ratio']}x")
    print(f"bench_risk,{out['decision_overhead_us'][RISK_POLICY]},{detail}")
    return out


if __name__ == "__main__":
    import sys
    main(sys.argv[1:])
