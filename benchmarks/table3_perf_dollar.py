"""Table 3 + Fig. 11: performance-per-dollar of the selected instances on
compute-bound applications (compilation / video encoding / graph analytics),
where measured throughput scales with the CoreMark score — KubePACS picks
newer-generation hardware at slightly higher price, netting perf/$ gains."""

import numpy as np

from repro.core import (KubePACSProvisioner, Request, karpenter_like,
                        preprocess)

from . import common

#: requests/min per unit of (BS_core·pods); calibrated so a c5.xlarge-class
#: node serves ~9 compile jobs/min, ~31 video encodes/min (Table 3)
APP_THROUGHPUT = {"compilation": 9 / 20_000.0, "video_enc": 31 / 20_000.0,
                  "pagerank": 2 / 20_000.0}


def _pool_stats(pool):
    perf = sum(it.bs * it.pods * c for it, c in zip(pool.items, pool.counts))
    cost = pool.hourly_cost
    return perf, cost


def run(cat=None):
    cat = cat or common.catalog()
    req = Request(pods=12, cpu_per_pod=4, mem_per_pod=8)   # one pod/instance
    items = preprocess(cat, req)
    prov = KubePACSProvisioner()
    ours = prov.provision(req, cat).pool
    karp = karpenter_like(items, req.pods)
    p_ours, c_ours = _pool_stats(ours)
    p_karp, c_karp = _pool_stats(karp)
    # the paper's currency: price per processed request = cost / throughput;
    # throughput scales with the pool's aggregate benchmark score
    out = {"us_per_call": 0.0}
    for app, k in APP_THROUGHPUT.items():
        rpm_ours = k * p_ours / max(ours.total_pods, 1) * req.pods
        rpm_karp = k * p_karp / max(karp.total_pods, 1) * req.pods
        ppr_ours = c_ours / max(rpm_ours * 60, 1e-9)
        ppr_karp = c_karp / max(rpm_karp * 60, 1e-9)
        out[app] = {
            "req_per_min_gain_pct": 100 * (rpm_ours / rpm_karp - 1),
            "price_per_req_reduction_pct": 100 * (1 - ppr_ours / ppr_karp),
        }
    out["perf_per_dollar_gain_pct"] = 100 * (
        (p_ours / c_ours) / (p_karp / c_karp) - 1)
    out["price_increase_pct"] = 100 * (c_ours / c_karp - 1)
    return out


def main():
    out = run()
    ve = out["video_enc"]
    print(f"table3_perf_dollar,0,"
          f"perf_per_dollar=+{out['perf_per_dollar_gain_pct']:.1f}%;"
          f"price_delta={out['price_increase_pct']:+.1f}%;"
          f"video_enc_price_per_req=-{ve['price_per_req_reduction_pct']:.1f}%;"
          f"compile_price_per_req=-"
          f"{out['compilation']['price_per_req_reduction_pct']:.1f}%")
    return out


if __name__ == "__main__":
    main()
