"""Fig. 5a/5b: KubePACS vs Greedy / SpotVerse-Node / SpotVerse-Pod across the
20 scenarios — normalized E_Total and per-type concentration (availability).

Paper claims reproduced: KubePACS ≥ all baselines everywhere; average gains
of +48.11% (Greedy), +81.06% (SpotVerse-Node), +60.40% (SpotVerse-Pod) on
their real SpotLake archive; our synthetic archive reproduces the ordering
(magnitudes recorded in EXPERIMENTS.md).
"""

import numpy as np

from repro.core import (e_total, karpenter_like, kubepacs_greedy, preprocess,
                        spotverse)
from repro.core.gss import bracketed_gss

from . import common


def run(cat=None):
    cat = cat or common.catalog()
    rows, concentrations = [], {"kubepacs": [], "sv-node": []}
    t_total = 0.0
    for req in common.requests():
        items = preprocess(cat, req)
        (pool, trace) = bracketed_gss(items, req.pods, tolerance=0.01)[0:2]
        t_total += trace.wall_seconds
        ek = e_total(pool, req.pods)
        row = {"scenario": (req.pods, req.cpu_per_pod, req.mem_per_pod),
               "kubepacs": 1.0}
        for name, fn in (("greedy", kubepacs_greedy),
                         ("sv-node", lambda it, r: spotverse(it, r, "node")),
                         ("sv-pod", lambda it, r: spotverse(it, r, "pod")),
                         ("karpenter", karpenter_like)):
            row[name] = e_total(fn(items, req.pods), req.pods) / ek
        rows.append(row)
        concentrations["kubepacs"].append(max(pool.counts) if pool.counts else 0)
        svn = spotverse(items, req.pods, "node")
        concentrations["sv-node"].append(max(svn.counts) if svn.counts else 0)

    out = {"rows": rows, "us_per_call": t_total / len(rows) * 1e6}
    for name in ("greedy", "sv-node", "sv-pod", "karpenter"):
        rel = np.mean([r[name] for r in rows])
        out[f"improvement_vs_{name}_pct"] = 100 * (1 / rel - 1)
        out[f"max_improvement_vs_{name}_pct"] = 100 * (
            1 / min(r[name] for r in rows) - 1)
    out["wins"] = sum(1 for r in rows
                      if all(r[n] <= 1 + 1e-9 for n in
                             ("greedy", "sv-node", "sv-pod", "karpenter")))
    out["median_max_nodes_per_type_kubepacs"] = float(
        np.median(concentrations["kubepacs"]))
    out["median_max_nodes_per_type_svnode"] = float(
        np.median(concentrations["sv-node"]))
    return out


def main():
    out = run()
    print(f"fig5_sota,{out['us_per_call']:.0f},"
          f"wins={out['wins']}/20;"
          f"vs_greedy=+{out['improvement_vs_greedy_pct']:.1f}%;"
          f"vs_svnode=+{out['improvement_vs_sv-node_pct']:.1f}%;"
          f"vs_svpod=+{out['improvement_vs_sv-pod_pct']:.1f}%;"
          f"vs_karpenter=+{out['improvement_vs_karpenter_pct']:.1f}%;"
          f"conc_kubepacs={out['median_max_nodes_per_type_kubepacs']:.0f};"
          f"conc_svnode={out['median_max_nodes_per_type_svnode']:.0f}")
    return out


if __name__ == "__main__":
    main()
