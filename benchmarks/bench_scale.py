"""Demand-scale sweep: one provisioning decision from 5 k to 1 M pods
through the coarsening ladder (DESIGN.md §14).

The market is the ``high_demand_scenario`` shape — a generated catalog
with quarter-vCPU / quarter-GiB pods, so every offering's pod count is
``4·vCPU`` and the compiled market's ``pods_gcd`` is 8 — grown to 1600
offerings (≈1.64 M pod capacity) so the 1 M-pod row is feasible.  Each
row times a *full bracketed-GSS decision* (9-α prescan + golden
refinement, the paper's decision unit) with the default
:class:`~repro.core.CoarseningConfig`, which lands on

  * the **exact** tier below the 8192-pod residual threshold (every row
    at 5 k demand — byte-identical to the pre-§14 engine),
  * the **gcd** tier while ``residual/8 ≤ max_rows`` (provably exact),
  * the certified **approx** tier above that (greedy rate-order prefix +
    exact DP over the boundary residual window, a-posteriori LP gap
    certificate, automatic exact fallback on violation).

Honesty rails baked into the record:

  * *in-bench verification* — at every scale where the exact engine is
    still cheap (≤ ``VERIFY_MAX``), each prescan α is re-solved with
    coarsening disabled: exact/gcd rows must match **bitwise** and
    approx rows must sit inside their own certificate (and inside
    ``rel_gap`` of the true optimum).  The sweep refuses to time an
    unverified ladder;
  * the *exact-engine wall* is recorded alongside at the overlapping
    scales, so the speedup column is measured, not extrapolated (the
    1 M exact decision takes ~100 s on the dev host — it is only timed
    under ``--full-exact``);
  * the fused device plane is timed where jax is available: it accepts
    exact/gcd-regime batches on device and *declines* approx-regime
    batches to the host by design, so its 1 M row is an honest
    host-fallback number, not a device number.

Headline: ``scale_ratio_1m_vs_5k`` — the 1 M-pod decision wall over the
5 k-pod wall on the best backend.  The ISSUE 7 acceptance bar is ≤ 2.0.

Usage:
  python -m benchmarks.bench_scale [--smoke] [--json PATH] [--full-exact]

``make bench-scale`` refreshes the checked-in ``BENCH_scale.json``.
"""

from __future__ import annotations

import argparse
import json
import platform
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import (CoarseningConfig, DEFAULT_COARSENING, NumpyBackend,
                        Request, bracketed_gss_many, compile_market,
                        generate_catalog, jax_available, make_backend,
                        preprocess, solve_ilp)

#: ISSUE 7 acceptance bar: the 1M-pod decision within 2x of the 5k wall
TARGET_RATIO = 2.0
SCALES = (5_000, 20_000, 50_000, 200_000, 1_000_000)
SMOKE_SCALES = (5_000, 20_000, 1_000_000)
#: largest demand the exact engine is re-run at for in-bench verification
#: and the measured (not extrapolated) exact-wall column
VERIFY_MAX = 50_000
PRESCAN_GRID = tuple(i / 8 for i in range(9))
EXACT = CoarseningConfig(enabled=False)
TOLERANCE = 0.01

_fake_timer = lambda: 0.0                                  # noqa: E731


def build_market(max_offerings: int = 1600, seed: int = 17):
    """The high-demand market family at benchmark size: quarter-vCPU
    pods (pods_gcd = 8) over a generated catalog big enough that the
    1 M-pod row is feasible."""
    cat = generate_catalog(seed=seed, max_offerings=max_offerings)
    items = preprocess(cat, Request(pods=5_000, cpu_per_pod=0.25,
                                    mem_per_pod=0.25))
    return items, compile_market(items)


# ---------------------------------------------------------------------------
# In-bench verification: the ladder against the exact engine
# ---------------------------------------------------------------------------

def verify_scale(market, demand: int,
                 alphas: Sequence[float] = PRESCAN_GRID) -> Dict:
    """Cross-validate every coarse tier against the uncoarsened engine at
    one demand: exact/gcd/fallback rows bitwise-identical, approx rows
    inside their own a-posteriori certificate *and* inside ``rel_gap`` of
    the true optimum.  Raises on any violation — the sweep must not time
    a ladder it cannot verify."""
    tiers: Dict[str, int] = {}
    max_true_gap = 0.0
    for alpha in alphas:
        pc, sc = solve_ilp(market.items, demand, alpha, return_stats=True,
                           market=market, coarsening=DEFAULT_COARSENING)
        pe, se = solve_ilp(market.items, demand, alpha, return_stats=True,
                           market=market, coarsening=EXACT)
        assert (pc is None) == (pe is None), (demand, alpha)
        if pc is None:
            tiers["infeasible"] = tiers.get("infeasible", 0) + 1
            continue
        tiers[sc.coarse] = tiers.get(sc.coarse, 0) + 1
        if sc.coarse in ("exact", "gcd", "approx_fallback"):
            if pc != pe:
                raise AssertionError(
                    f"{sc.coarse} tier not bitwise at demand={demand} "
                    f"alpha={alpha}")
        else:                                  # certified approx tier
            true_gap = sc.objective - se.objective
            bound = sc.gap_bound + 1e-6 * max(1.0, abs(se.objective))
            rel = (DEFAULT_COARSENING.rel_gap * max(abs(se.objective), 1e-9)
                   + 1e-9)
            if not (true_gap <= bound and true_gap <= rel):
                raise AssertionError(
                    f"approx certificate violated at demand={demand} "
                    f"alpha={alpha}: true_gap={true_gap} "
                    f"cert={sc.gap_bound} rel_budget={rel}")
            max_true_gap = max(max_true_gap, true_gap)
        covered = sum(int(c) * it.pods
                      for c, it in zip(pc, market.items))
        assert covered >= demand, (demand, alpha, covered)
    return {"demand": demand, "alphas": len(alphas), "tiers": tiers,
            "max_true_gap": round(max_true_gap, 9), "verified": True}


# ---------------------------------------------------------------------------
# Timed sweep
# ---------------------------------------------------------------------------

def _interleaved(fns: Dict[str, callable], repeat: int) -> Dict[str, float]:
    """min-of-N wall per contender, visit order rotated each round (same
    thermal-drift rationale as bench_backend)."""
    names = list(fns)
    best = {k: float("inf") for k in names}
    for r in range(repeat):
        order = names[r % len(names):] + names[: r % len(names)]
        for k in order:
            t0 = time.perf_counter()
            fns[k]()
            best[k] = min(best[k], time.perf_counter() - t0)
    return best


def _gss(items, market, demand: int, backend, cfg) -> Optional[object]:
    out = bracketed_gss_many(items, [demand], tolerance=TOLERANCE,
                             market=market, timer=_fake_timer,
                             backend=backend, coarsening=cfg)
    return out[0][0]


def _tier_column(market, demand: int) -> Dict:
    """Which ladder rung each prescan α lands on (stats only, no timing)."""
    tiers: Dict[str, int] = {}
    max_cert = 0.0
    for alpha in PRESCAN_GRID:
        _, st = solve_ilp(market.items, demand, alpha, return_stats=True,
                          market=market, coarsening=DEFAULT_COARSENING)
        tiers[st.coarse] = tiers.get(st.coarse, 0) + 1
        max_cert = max(max_cert, st.gap_bound)
    return {"tiers": tiers, "max_gap_certificate": round(max_cert, 9)}


def bench_scales(scales: Sequence[int] = SCALES, *, repeat: int = 3,
                 full_exact: bool = False,
                 max_offerings: int = 1600) -> Tuple[List[Dict], Dict]:
    """The sweep: per scale, one full bracketed-GSS decision timed
    interleaved per backend under the default ladder; the exact engine
    timed alongside up to ``VERIFY_MAX`` (or everywhere with
    ``full_exact``); verification run before any timing."""
    items, market = build_market(max_offerings=max_offerings)
    numpy_be = NumpyBackend()
    fused_be = make_backend("jax:fused") if jax_available() else None

    rows: List[Dict] = []
    for demand in scales:
        row: Dict = {"pods": demand, **_tier_column(market, demand)}
        if demand <= VERIFY_MAX:
            row["verify"] = verify_scale(market, demand)
        # equality gate across backends before timing
        pool_n = _gss(items, market, demand, numpy_be, DEFAULT_COARSENING)
        fns = {"numpy": lambda: _gss(items, market, demand, numpy_be,
                                     DEFAULT_COARSENING)}
        if fused_be is not None:
            pool_f = _gss(items, market, demand, fused_be,
                          DEFAULT_COARSENING)          # warm (XLA compile)
            row["fused_selection_equal_numpy"] = (
                (pool_n is None) == (pool_f is None)
                and (pool_n is None or pool_n.as_dict() == pool_f.as_dict()))
            if not row["fused_selection_equal_numpy"]:
                raise AssertionError(
                    f"fused selection diverged at demand={demand}")
            fns["fused"] = lambda: _gss(items, market, demand, fused_be,
                                        DEFAULT_COARSENING)
        if full_exact or demand <= VERIFY_MAX:
            fns["exact_numpy"] = lambda: _gss(items, market, demand,
                                              numpy_be, EXACT)
        best = _interleaved(fns, repeat)
        for name, wall in best.items():
            row[f"{name}_wall_s"] = round(wall, 4)
        row["best_wall_s"] = round(
            min(w for k, w in best.items() if k != "exact_numpy"), 4)
        if "exact_numpy" in best:
            row["coarse_speedup_vs_exact"] = round(
                best["exact_numpy"] / row["best_wall_s"], 2)
        rows.append(row)

    meta = {"n_items": market.n, "pods_gcd": int(market.pods_gcd),
            "capacity_pods": int(np.sum(market.pods * market.bound)),
            "max_offerings": max_offerings,
            "coarsening": {"threshold": DEFAULT_COARSENING.threshold,
                           "max_rows": DEFAULT_COARSENING.max_rows,
                           "window": DEFAULT_COARSENING.approx_rows,
                           "rel_gap": DEFAULT_COARSENING.rel_gap}}
    return rows, meta


def gate_measurement(repeat: int = 3) -> Dict:
    """The cheap perf-gate slice: the 1 M vs 5 k decision-wall ratio on
    the host engine plus a bitwise gcd-tier spot check (benchmarks/
    perf_gate.py gates the ratio inside a tolerance band)."""
    items, market = build_market()
    numpy_be = NumpyBackend()
    gcd_ok = True
    try:
        verify_scale(market, 20_000, alphas=(0.0, 0.125))
    except AssertionError:
        gcd_ok = False
    best = _interleaved(
        {"w5k": lambda: _gss(items, market, 5_000, numpy_be,
                             DEFAULT_COARSENING),
         "w1m": lambda: _gss(items, market, 1_000_000, numpy_be,
                             DEFAULT_COARSENING)}, repeat)
    return {"ratio": round(best["w1m"] / best["w5k"], 2),
            "wall_5k_s": round(best["w5k"], 4),
            "wall_1m_s": round(best["w1m"], 4),
            "gcd_bitwise_ok": gcd_ok}


def run(smoke: bool = False, json_path: Optional[str] = None,
        repeat: Optional[int] = None, full_exact: bool = False) -> Dict:
    scales = SMOKE_SCALES if smoke else SCALES
    rows, meta = bench_scales(scales, repeat=repeat or (1 if smoke else 3),
                              full_exact=full_exact)
    by_pods = {r["pods"]: r for r in rows}
    ratio = round(by_pods[1_000_000]["best_wall_s"]
                  / by_pods[5_000]["best_wall_s"], 2)
    verified = [r["pods"] for r in rows if r.get("verify")]
    out = {
        "benchmark": "bench_scale",
        "python": platform.python_version(),
        "numpy": np.__version__,
        "machine": platform.machine(),
        "market": meta,
        "scales": rows,
        "headline": {
            "scale_ratio_1m_vs_5k": ratio,
            "meets_2x_target": ratio <= TARGET_RATIO,
            "verified_scales": verified,
            "coarse_speedup_vs_exact_50k":
                by_pods.get(50_000, {}).get("coarse_speedup_vs_exact"),
        },
    }
    if json_path:
        with open(json_path, "w") as f:
            json.dump(out, f, indent=2)
    return out


def main(argv: Optional[List[str]] = None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="3 scales, 1 timing round (CI)")
    ap.add_argument("--json", default="",
                    help="output record path (e.g. BENCH_scale.json)")
    ap.add_argument("--repeat", type=int, default=None,
                    help="interleaved timing rounds (default 3; 1 smoke)")
    ap.add_argument("--full-exact", action="store_true",
                    help="also time the exact engine above VERIFY_MAX "
                         "(the 1M exact decision takes minutes)")
    args = ap.parse_args(argv if argv is not None else [])
    out = run(smoke=args.smoke, json_path=args.json or None,
              repeat=args.repeat, full_exact=args.full_exact)
    h = out["headline"]
    w1m = next(r for r in out["scales"] if r["pods"] == 1_000_000)
    detail = (f"ratio_1m_vs_5k:{h['scale_ratio_1m_vs_5k']}x"
              f";meets_2x:{h['meets_2x_target']}"
              f";verified:{h['verified_scales']}"
              f";1m_best:{w1m['best_wall_s']}s")
    print(f"bench_scale,{round(w1m['best_wall_s'] * 1e6)},{detail}")
    return out


if __name__ == "__main__":
    import sys
    main(sys.argv[1:])
