"""ChaosPlane benchmark: hardened vs naive control plane under fault
storms (DESIGN.md §16).

Emits ``BENCH_chaos.json`` — FleetSim fault-storm sweeps comparing the
``hardened`` policy (degradation ladder) against plain ``kubepacs``
(naive: decides on whatever the corrupted feed says, loses whole decision
cycles to solver faults):

  * per storm (``combined`` headline; ``feed`` / ``ice`` / ``solver`` in
    the full run), both policies face the byte-identical fault schedule,
    market path, and interrupt streams;
  * **SLO perf-per-dollar** — delivered useful perf-hours per dollar with
    unserved demand backfilled at on-demand rates: every pod-hour of
    demand the spot plane failed to cover is charged (and credited) at
    the catalog's cheapest on-demand rate per pod, which is what a real
    operator pays when the spot plane is down.  Raw perf-per-dollar alone
    rewards dropping the cluster (idle capacity is cheap); the backfill
    accounting makes unavailability cost what it actually costs;
  * ``headline.chaos_hardened_vs_naive_ratio`` — hardened over naive on
    SLO perf-per-dollar, combined storm — must meet ``TARGET_RATIO``
    with hardened decision availability ≥ ``TARGET_AVAILABILITY``;
  * before measuring, the bench re-proves the determinism contract under
    chaos (same seed ⇒ byte-identical JSONL trace; replay RNG-free) and
    the **inertness contract** (faults disabled ⇒ hardened trace byte-
    identical to kubepacs) — comparisons against a non-reproducible or
    non-inert hardening layer would be meaningless, so these raise.

Usage:
  python -m benchmarks.bench_chaos [--smoke] [--json PATH]

``make bench-chaos`` refreshes the checked-in BENCH_chaos.json.
"""

from __future__ import annotations

import argparse
import json
import platform
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.chaos import fault_storm
from repro.chaos.guard import decision_available
from repro.core.provisioner import preprocess
from repro.sim.engine import ClusterSim, SimResult
from repro.sim.fleet import run_fleet
from repro.sim.scenario import Scenario

#: acceptance bar (ISSUE 9): hardened ≥ 1.3× naive SLO perf-per-dollar
#: under the combined storm, at ≥ 0.95 decision availability
TARGET_RATIO = 1.3
TARGET_AVAILABILITY = 0.95

STORMS = ("feed", "ice", "solver", "combined")
POLICIES = ("hardened", "kubepacs")

_DENOM_FLOOR = 1e-9


def chaos_scenario(storm: Optional[str], policy: str) -> Scenario:
    """The pinned 48 h / 3 h-step storm scenario: the fault windows of
    :func:`repro.chaos.fault_storm` are laid out for exactly this grid
    (every window edge on a tick boundary, so fleet memo keys can never
    straddle a fault phase change)."""
    return Scenario(
        name=f"chaos_{storm or 'clean'}", duration_hours=48.0,
        step_hours=3.0, pods=160,
        demand_schedule=((12.0, 220), (24.0, 140)),
        interrupt_model="pressure", policy=policy,
        catalog_seed=7, max_offerings=150, market_seed=7,
        interrupt_seed=7,
        faults=fault_storm(storm) if storm else ())


def od_backfill_rate(scenario: Scenario) -> Tuple[float, float]:
    """(od $/pod-hour, perf/pod-hour) of the catalog's cheapest-per-pod
    on-demand offering — the deterministic reference rate unserved demand
    is billed (and credited) at."""
    items = preprocess(scenario.build_catalog(), scenario.request())
    best = min(items, key=lambda it: (it.offering.od_price / it.pods,
                                      it.offering.offering_id))
    return best.offering.od_price / best.pods, best.bs


def _demand_at(scenario: Scenario, t: float) -> int:
    pods = scenario.pods
    for ts, p in scenario.demand_schedule:
        if ts <= t + 1e-9:
            pods = p
    return int(pods)


def slo_metrics(result: SimResult, od_rate: float,
                od_perf: float) -> Dict[str, float]:
    """Per-run metrics: raw and SLO (backfilled) perf-per-dollar plus
    decision availability and demand coverage."""
    sc = result.scenario
    deficit_pod_hours = 0.0
    demand_pod_hours = 0.0
    prev_t = 0.0
    for rd in result.rounds:
        dt = rd.time - prev_t
        demand = _demand_at(sc, rd.time)
        deficit_pod_hours += max(0, demand - rd.pool.total_pods) * dt
        demand_pod_hours += demand * dt
        prev_t = rd.time
    backfill_cost = deficit_pod_hours * od_rate
    backfill_perf = deficit_pod_hours * od_perf
    avail = [decision_available(d) for _, d in result.decisions]
    raw_ppd = result.total_perf_hours / max(result.total_cost,
                                            _DENOM_FLOOR)
    slo_ppd = ((result.total_perf_hours + backfill_perf)
               / max(result.total_cost + backfill_cost, _DENOM_FLOOR))
    return {
        "perf_hours": round(result.total_perf_hours, 3),
        "cost": round(result.total_cost, 4),
        "raw_perf_per_dollar": round(raw_ppd, 2),
        "slo_perf_per_dollar": round(slo_ppd, 2),
        "deficit_pod_hours": round(deficit_pod_hours, 2),
        "demand_coverage": round(
            1.0 - deficit_pod_hours / max(demand_pod_hours, _DENOM_FLOOR),
            4),
        "decision_availability": round(
            sum(avail) / max(len(avail), 1), 4),
        "decisions": len(avail),
        "interrupted_nodes": result.interrupted_nodes,
    }


def _mean(rows: List[Dict[str, float]], key: str) -> float:
    return float(np.mean([r[key] for r in rows]))


def _contract_checks() -> Dict[str, bool]:
    """Determinism under chaos + inertness of the hardening layer."""
    sc = chaos_scenario("combined", "hardened")
    a = ClusterSim(sc, clock=lambda: 0.0).run()
    b = ClusterSim(sc, clock=lambda: 0.0).run()
    determinism = a.recorder.dumps() == b.recorder.dumps()
    replay = (ClusterSim.replay(a.records).run().recorder.dumps()
              == a.recorder.dumps())
    # faults disabled ⇒ hardened is bit-identical to kubepacs (the guard's
    # healthy path literally delegates to the contained provisioner)
    clean_h = ClusterSim(chaos_scenario(None, "hardened"),
                         clock=lambda: 0.0).run()
    clean_k = ClusterSim(chaos_scenario(None, "kubepacs"),
                         clock=lambda: 0.0).run()
    ha = clean_h.recorder.dumps().replace('"policy": "hardened"',
                                          '"policy": "kubepacs"')
    inert = ha == clean_k.recorder.dumps()
    return {"determinism_ok": determinism, "replay_ok": replay,
            "inert_ok": inert}


def _sweep(storm: str, seeds: List[int], od_rate: float,
           od_perf: float) -> Dict[str, Dict]:
    rows = {}
    for policy in POLICIES:
        sc = chaos_scenario(storm, policy)
        t0 = time.perf_counter()
        results = run_fleet(sc, seeds, clock=lambda: 0.0)
        wall = time.perf_counter() - t0
        per_seed = [slo_metrics(r, od_rate, od_perf) for r in results]
        agg = {k: round(_mean(per_seed, k), 4)
               for k in ("raw_perf_per_dollar", "slo_perf_per_dollar",
                         "decision_availability", "demand_coverage",
                         "deficit_pod_hours", "cost")}
        agg["wall_s"] = round(wall, 3)
        agg["per_seed"] = per_seed
        if policy == "hardened":
            agg["ladder"] = {k: v for k, v in
                             results[0].cache_stats.items()
                             if k.startswith("chaos_")}
        rows[policy] = agg
    return rows


def run(smoke: bool = False, json_path: Optional[str] = None) -> dict:
    seeds = [7] if smoke else [3, 7, 11]
    storms = ("combined",) if smoke else STORMS

    checks = _contract_checks()
    if not all(checks.values()):
        raise AssertionError(
            f"chaos contracts violated: {checks} — the determinism/"
            "inertness guarantees are preconditions for a meaningful "
            "hardened-vs-naive comparison")

    od_rate, od_perf = od_backfill_rate(chaos_scenario(None, "kubepacs"))
    sweeps = {storm: _sweep(storm, seeds, od_rate, od_perf)
              for storm in storms}

    hard = sweeps["combined"]["hardened"]
    naive = sweeps["combined"]["kubepacs"]
    ratio = hard["slo_perf_per_dollar"] / max(naive["slo_perf_per_dollar"],
                                              _DENOM_FLOOR)
    out = {
        "benchmark": "bench_chaos",
        "python": platform.python_version(),
        "numpy": np.__version__,
        "machine": platform.machine(),
        "seeds": seeds,
        "od_backfill_rate_per_pod_hour": round(od_rate, 6),
        "od_backfill_perf_per_pod_hour": round(od_perf, 4),
        "target_ratio": TARGET_RATIO,
        "target_availability": TARGET_AVAILABILITY,
        "contracts": checks,
        "storms": sweeps,
        "headline": {
            "chaos_hardened_vs_naive_ratio": round(ratio, 3),
            "hardened_availability": hard["decision_availability"],
            "naive_availability": naive["decision_availability"],
            "hardened_slo_perf_per_dollar": hard["slo_perf_per_dollar"],
            "naive_slo_perf_per_dollar": naive["slo_perf_per_dollar"],
            "hardened_demand_coverage": hard["demand_coverage"],
            "naive_demand_coverage": naive["demand_coverage"],
            "availability_ok": (hard["decision_availability"]
                                >= TARGET_AVAILABILITY),
            "meets_target": (ratio >= TARGET_RATIO
                             and hard["decision_availability"]
                             >= TARGET_AVAILABILITY),
        },
    }
    if json_path:
        with open(json_path, "w") as f:
            json.dump(out, f, indent=2)
    return out


def gate_measurement(repeat: int = 1) -> dict:
    """The ``make perf-gate`` metrics.  The sweep is numpy-engine
    deterministic (FleetSim decisions are backend-bitwise by the DESIGN
    §12 contract), so the ratio is identical on the jax and no-jax legs
    and one run suffices; ``repeat`` is accepted for signature parity."""
    checks = _contract_checks()
    od_rate, od_perf = od_backfill_rate(chaos_scenario(None, "kubepacs"))
    rows = _sweep("combined", [7], od_rate, od_perf)
    hard, naive = rows["hardened"], rows["kubepacs"]
    ratio = hard["slo_perf_per_dollar"] / max(naive["slo_perf_per_dollar"],
                                              _DENOM_FLOOR)
    return {
        "chaos_hardened_vs_naive_ratio": round(ratio, 3),
        "availability_ok": (hard["decision_availability"]
                            >= TARGET_AVAILABILITY),
        "determinism_ok": checks["determinism_ok"] and checks["replay_ok"],
        "inert_ok": checks["inert_ok"],
        "hardened_availability": hard["decision_availability"],
    }


def main(argv: Optional[List[str]] = None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="combined storm only, one seed (CI)")
    ap.add_argument("--json", default="",
                    help="output record path (e.g. BENCH_chaos.json; "
                         "default: don't write)")
    args = ap.parse_args(argv if argv is not None else [])
    out = run(smoke=args.smoke, json_path=args.json or None)
    h = out["headline"]
    detail = (f"slo_ppd_ratio={h['chaos_hardened_vs_naive_ratio']}x"
              f";avail={h['hardened_availability']}"
              f"vs{h['naive_availability']}"
              f";coverage={h['hardened_demand_coverage']}"
              f"vs{h['naive_demand_coverage']}"
              f";target>={out['target_ratio']}x:"
              f"{'met' if h['meets_target'] else 'MISSED'}")
    wall = out["storms"]["combined"]["hardened"]["wall_s"]
    print(f"bench_chaos,{round(wall * 1e6)},{detail}")
    return out


if __name__ == "__main__":
    import sys
    main(sys.argv[1:])
