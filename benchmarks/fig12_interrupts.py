"""Fig. 12: interrupt handling — replacement cost, performance, and recovery
latency of the §4.1 loop vs a Karpenter-like re-provision (which re-ranks by
price-capacity and pays SpotFleet-call latency; we charge it the documented
~2 s service latency vs our measured solver wall time)."""

import time

import numpy as np

from repro.core import (InterruptEvent, KubePACSProvisioner, Request,
                        SpotMarketSimulator, e_perf_cost, karpenter_like,
                        preprocess)

from . import common

KARPENTER_SERVICE_LATENCY_S = 2.0     # SpotFleet recommendation round-trip


def run(cat=None, rounds: int = 6):
    cat = cat or common.catalog()
    sim = SpotMarketSimulator(cat, seed=1)
    prov = KubePACSProvisioner()
    req = Request(pods=100, cpu_per_pod=2, mem_per_pod=2)
    ours_cost, ours_perf, ours_rec = [], [], []
    karp_cost, karp_perf = [], []
    d = prov.provision(req, sim.snapshot())
    pool = d.pool
    for _ in range(rounds):
        sim.step(6.0)
        prov.clock = sim.time
        events = sim.interrupts_for_pool(pool.as_dict(), hours=6.0)
        if not events:
            # force one: kill the largest allocation (fault injection, §5.4.3)
            worst = max(zip(pool.items, pool.counts), key=lambda ic: ic[1])
            events = [InterruptEvent(time=sim.time,
                                     offering_id=worst[0].offering.offering_id,
                                     count=worst[1])]
        lost_pods = sum(e.count for e in events) * 2
        survivors = max(0, pool.total_pods - lost_pods)
        prov.enqueue(events)
        # one snapshot per round: both provisioners see the same market
        snap = sim.snapshot()
        t0 = time.perf_counter()
        repl = prov.handle_interrupts(req, snap, surviving_pods=survivors)
        ours_rec.append(time.perf_counter() - t0)
        # Fig. 12a/b compare the recommended instance TYPES: per-node spot
        # price (box plot) and per-node benchmark score
        if repl and repl.pool.total_nodes:
            n = repl.pool.total_nodes
            ours_cost.append(repl.pool.hourly_cost / n)
            ours_perf.append(sum(it.bs * c for it, c in
                                 zip(repl.pool.items, repl.pool.counts)) / n)
        items = preprocess(snap, req)
        kp = karpenter_like(items, max(1, req.pods - survivors))
        if kp.total_nodes:
            karp_cost.append(kp.hourly_cost / kp.total_nodes)
            karp_perf.append(sum(it.bs * c for it, c in
                                 zip(kp.items, kp.counts)) / kp.total_nodes)
    return {
        "node_price_ours": float(np.mean(ours_cost)),
        "node_price_karpenter": float(np.mean(karp_cost)),
        "cost_reduction_pct": 100 * (1 - np.mean(ours_cost) /
                                     np.mean(karp_cost)),
        "node_score_ratio": float(np.mean(ours_perf) / np.mean(karp_perf)),
        "recovery_s_ours": float(np.mean(ours_rec)),
        "recovery_s_karpenter": KARPENTER_SERVICE_LATENCY_S,
        "us_per_call": float(np.mean(ours_rec)) * 1e6,
    }


def main():
    out = run()
    print(f"fig12_interrupts,{out['us_per_call']:.0f},"
          f"repl_node_price_reduction={out['cost_reduction_pct']:.1f}%;"
          f"node_score_x{out['node_score_ratio']:.2f};"
          f"recovery_ours={out['recovery_s_ours']:.2f}s_vs_karpenter~"
          f"{out['recovery_s_karpenter']:.1f}s")
    return out


if __name__ == "__main__":
    main()
