"""Fig. 12: interrupt handling — replacement cost, performance, and recovery
latency of the §4.1 loop vs a Karpenter-like re-provision (which re-ranks by
price-capacity and pays SpotFleet-call latency; we charge it the documented
~2 s service latency vs our measured solver wall time).

Re-derived as a scenario: a 6-round interrupt storm (pressure sampler +
§5.4.3 fault injection when a round is calm) run through the scenario
engine, which also fixes the seed's lost-pod accounting — losses are
counted with each pool item's actual ``CandidateItem.pods`` capacity, not
a hardcoded 2 pods/node, so large-instance interrupts are no longer
undercounted."""

import numpy as np

from repro.core import Request, karpenter_like, preprocess
from repro.sim import ClusterSim, Scenario

from . import common

KARPENTER_SERVICE_LATENCY_S = 2.0     # SpotFleet recommendation round-trip


def scenario(rounds: int = 6, max_offerings: int = 2000) -> Scenario:
    return Scenario(
        name="fig12_interrupts",
        duration_hours=rounds * 6.0, step_hours=6.0,
        pods=100, cpu_per_pod=2, mem_per_pod=2,
        interrupt_model="pressure", inject_if_idle=True,
        policy="kubepacs",
        catalog_seed=0, max_offerings=max_offerings,
        market_seed=1, interrupt_seed=1,
    )


def run(cat=None, rounds: int = 6):
    cat = cat or common.catalog()
    sc = scenario(rounds, max_offerings=len(cat))
    res = ClusterSim(sc, catalog=cat, keep_snapshots=True).run()
    req = Request(pods=sc.pods, cpu_per_pod=sc.cpu_per_pod,
                  mem_per_pod=sc.mem_per_pod)

    ours_cost, ours_perf, ours_rec = [], [], []
    karp_cost, karp_perf = [], []
    for rd in res.rounds:
        if rd.decision is not None:
            ours_rec.append(rd.decision.wall_seconds)
            # Fig. 12a/b compare the recommended instance TYPES: per-node
            # spot price (box plot) and per-node benchmark score
            repl = rd.decision.pool
            if repl.total_nodes:
                n = repl.total_nodes
                ours_cost.append(repl.hourly_cost / n)
                ours_perf.append(sum(it.bs * c for it, c in
                                     zip(repl.items, repl.counts)) / n)
        # the baseline re-provisions every round (as the seed driver did),
        # against the identical snapshot and shortfall
        items = preprocess(rd.snapshot, req)
        kp = karpenter_like(items, max(1, rd.shortfall))
        if kp.total_nodes:
            karp_cost.append(kp.hourly_cost / kp.total_nodes)
            karp_perf.append(sum(it.bs * c for it, c in
                                 zip(kp.items, kp.counts)) / kp.total_nodes)

    def mean(xs):
        return float(np.mean(xs)) if xs else float("nan")

    return {
        "node_price_ours": mean(ours_cost),
        "node_price_karpenter": mean(karp_cost),
        "cost_reduction_pct": 100 * (1 - mean(ours_cost) / mean(karp_cost))
        if ours_cost and karp_cost else float("nan"),
        "node_score_ratio": mean(ours_perf) / mean(karp_perf)
        if ours_perf and karp_perf else float("nan"),
        "recovery_s_ours": mean(ours_rec),
        "recovery_s_karpenter": KARPENTER_SERVICE_LATENCY_S,
        "lost_pods_total": int(sum(rd.lost_pods for rd in res.rounds)),
        "interrupted_nodes": res.interrupted_nodes,
        "us_per_call": mean(ours_rec) * 1e6 if ours_rec else 0.0,
    }


def main():
    out = run()
    print(f"fig12_interrupts,{out['us_per_call']:.0f},"
          f"repl_node_price_reduction={out['cost_reduction_pct']:.1f}%;"
          f"node_score_x{out['node_score_ratio']:.2f};"
          f"recovery_ours={out['recovery_s_ours']:.2f}s_vs_karpenter~"
          f"{out['recovery_s_karpenter']:.1f}s;"
          f"lost_pods={out['lost_pods_total']}")
    return out


if __name__ == "__main__":
    main()
