"""Serving co-simulation benchmark: served QPS per dollar under SLO
(DESIGN.md §15).

Emits ``BENCH_serve.json`` — the fig-style policy comparison for the
serving scenario family:

  * per workload (``diurnal`` headline; ``bursty`` / ``flash`` in the
    full run) every policy provisions the *same* square-root-staffed pod
    demand, and the report integrates served / SLO-served QPS-hours
    against each policy's capacity timeline (recovery warm-up charged);
  * ``headline.serve_qps_per_dollar_ratio`` — serving_slo over
    karpenter_like on SLO-served QPS-hours per dollar, diurnal — must
    meet ``TARGET_SLO_QPS_RATIO`` at equal-or-better SLO attainment;
  * before timing anything the bench re-proves the determinism contract
    (same seed ⇒ identical workload trace digest AND an identical serving
    report on a re-run) and asserts **zero SLO-mask infeasibilities** for
    serving_slo on the pinned market — a comparison against an infeasible
    or non-reproducible run would be meaningless, so these raise.

``gate_measurement()`` is the ``make perf-gate`` entry point: it pins the
*analytic* perf-model mode (via the ``KUBEPACS_SERVE_PERF`` env override)
so the gated ratio is identical on the jax and no-jax CI legs; the main
comparison deliberately runs in the ambient mode instead, which is how
the jax leg exercises the roofline table and the no-jax leg the analytic
fallback end to end.

Usage:
  python -m benchmarks.bench_serve [--smoke] [--json PATH]

``make bench-serve`` refreshes the checked-in BENCH_serve.json.
"""

from __future__ import annotations

import argparse
import contextlib
import json
import os
import platform
import time
from typing import List, Optional

import numpy as np

from repro.serve_sim import (WorkloadSpec, build_serve_scenario,
                             clear_caches, run_serving, trace_digest)
from repro.serve_sim.perf_model import ENV_MODE

#: acceptance bar (ISSUE 8): serving_slo ≥ 1.2× SLO-served QPS-hours per
#: dollar over karpenter_like on the diurnal scenario, at equal-or-better
#: SLO attainment
TARGET_SLO_QPS_RATIO = 1.2

POLICIES = ("serving_slo", "karpenter_like", "kubepacs",
            "fixed_alpha:0.5", "kubepacs_risk")

#: ratio denominators are floored so one pathological karpenter run (zero
#: SLO-served traffic) reports a huge finite ratio instead of inf/NaN
_DENOM_FLOOR = 1e-9


@contextlib.contextmanager
def _pinned_mode(mode: str):
    """Temporarily pin the perf-model mode (policy + staffing + report all
    resolve ``default_profile`` → the env override)."""
    old = os.environ.get(ENV_MODE)
    os.environ[ENV_MODE] = mode
    clear_caches()           # tables keyed by mode-inclusive digest anyway;
    try:                     # cleared so counters reflect this block only
        yield
    finally:
        if old is None:
            del os.environ[ENV_MODE]
        else:
            os.environ[ENV_MODE] = old


def _run_policy(kind: str, policy: str, duration_hours: float) -> tuple:
    ss = build_serve_scenario(kind, policy=policy,
                              duration_hours=duration_hours)
    t0 = time.perf_counter()
    report = run_serving(ss, clock=lambda: 0.0)
    return report, time.perf_counter() - t0


def _determinism_check(duration_hours: float) -> bool:
    """Same seed ⇒ byte-identical trace digest; same scenario ⇒ identical
    serving report (policies are replay-RNG-free, the table is digest-
    cached, and the integration is exact)."""
    spec = WorkloadSpec(kind="diurnal", seed=123)
    if trace_digest(spec) != trace_digest(WorkloadSpec(kind="diurnal",
                                                       seed=123)):
        return False
    a, _ = _run_policy("diurnal", "serving_slo", duration_hours)
    b, _ = _run_policy("diurnal", "serving_slo", duration_hours)
    return a.as_dict() == b.as_dict()


def _compare(kind: str, policies, duration_hours: float) -> dict:
    rows = {}
    for policy in policies:
        report, wall = _run_policy(kind, policy, duration_hours)
        d = report.as_dict()
        d["wall_s"] = round(wall, 3)
        rows[policy] = d
    return rows


def run(smoke: bool = False, json_path: Optional[str] = None) -> dict:
    duration = 12.0 if smoke else 24.0
    kinds = ("diurnal",) if smoke else ("diurnal", "bursty", "flash")

    if not _determinism_check(duration):
        raise AssertionError(
            "serving co-sim is not deterministic: same seed produced a "
            "different trace digest or serving report — refusing to "
            "benchmark a non-reproducible run")

    comparisons = {kind: _compare(kind, POLICIES, duration)
                   for kind in kinds}

    slo = comparisons["diurnal"]["serving_slo"]
    karp = comparisons["diurnal"]["karpenter_like"]
    if slo["infeasible_decisions"]:
        raise AssertionError(
            f"serving_slo hit {slo['infeasible_decisions']} SLO-mask "
            "infeasibilities on the pinned market — the mask is "
            "over-constraining the ILP (acceptance: zero)")
    ratio = slo["slo_qps_hours_per_dollar"] / max(
        karp["slo_qps_hours_per_dollar"], _DENOM_FLOOR)
    attainment_ok = slo["slo_attainment"] >= karp["slo_attainment"] - 1e-9

    out = {
        "benchmark": "bench_serve",
        "python": platform.python_version(),
        "numpy": np.__version__,
        "machine": platform.machine(),
        "perf_mode": slo["perf_mode"],
        "duration_hours": duration,
        "slo_ms": slo["slo_ms"],
        "workload_digest": slo["workload_digest"],
        "determinism_checked": True,
        "target_slo_qps_ratio": TARGET_SLO_QPS_RATIO,
        "comparisons": comparisons,
        "headline": {
            "serve_qps_per_dollar_ratio": round(ratio, 3),
            "serving_slo_attainment": round(slo["slo_attainment"], 4),
            "karpenter_attainment": round(karp["slo_attainment"], 4),
            "attainment_ok": attainment_ok,
            "serving_slo_qps_per_dollar":
                round(slo["slo_qps_hours_per_dollar"], 2),
            "karpenter_slo_qps_per_dollar":
                round(karp["slo_qps_hours_per_dollar"], 2),
            "infeasible_decisions": slo["infeasible_decisions"],
            "meets_target": (ratio >= TARGET_SLO_QPS_RATIO
                             and attainment_ok),
        },
    }
    if json_path:
        with open(json_path, "w") as f:
            json.dump(out, f, indent=2)
    return out


def gate_measurement(repeat: int = 1) -> dict:
    """The ``make perf-gate`` metrics, pinned to the analytic perf-model
    mode so the ratio is identical on the jax and no-jax CI legs (mode
    changes pod counts and absolute latencies; the gate must not see
    that as a regression).  ``repeat`` is accepted for signature parity
    with the other gate measurements — the serving ratio is exact
    (integral of deterministic step functions), not a timing, so one run
    suffices."""
    with _pinned_mode("analytic"):
        determinism_ok = _determinism_check(12.0)
        rows = _compare("diurnal", ("serving_slo", "karpenter_like"), 12.0)
    slo, karp = rows["serving_slo"], rows["karpenter_like"]
    ratio = slo["slo_qps_hours_per_dollar"] / max(
        karp["slo_qps_hours_per_dollar"], _DENOM_FLOOR)
    return {
        "serve_qps_per_dollar_ratio": round(ratio, 3),
        "attainment_ok": (slo["slo_attainment"]
                          >= karp["slo_attainment"] - 1e-9),
        "infeasible_free": slo["infeasible_decisions"] == 0,
        "determinism_ok": determinism_ok,
        "serving_slo_attainment": round(slo["slo_attainment"], 4),
    }


def main(argv: Optional[List[str]] = None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="diurnal only, 12 h horizon (CI)")
    ap.add_argument("--json", default="",
                    help="output record path (e.g. BENCH_serve.json; "
                         "default: don't write)")
    args = ap.parse_args(argv if argv is not None else [])
    out = run(smoke=args.smoke, json_path=args.json or None)
    h = out["headline"]
    detail = (f"mode={out['perf_mode']}"
              f";slo_qps_ratio={h['serve_qps_per_dollar_ratio']}x"
              f";att={h['serving_slo_attainment']}"
              f"vs{h['karpenter_attainment']}"
              f";infeasible={h['infeasible_decisions']}"
              f";target>={out['target_slo_qps_ratio']}x:"
              f"{'met' if h['meets_target'] else 'MISSED'}")
    wall = out["comparisons"]["diurnal"]["serving_slo"]["wall_s"]
    print(f"bench_serve,{round(wall * 1e6)},{detail}")
    return out


if __name__ == "__main__":
    import sys
    main(sys.argv[1:])
