"""Fig. 6b: cross-provider generalization — the same ILP×GSS pipeline on an
Azure-like market (different price anchors, sparser SPS coverage: the paper
reports only 17.9% of Azure candidates kept consistently-valid SPS and
~15% lower absolute E_Total; the concave α landscape is preserved)."""

import numpy as np

from repro.core import (Request, compile_market, e_total, generate_catalog,
                        preprocess, score_counts_batch, solve_ilp_batch)
from repro.core.gss import bracketed_gss
from repro.core.market import FAMILY_SPECS


def azure_like_catalog(seed: int = 42):
    """Azure-flavoured market: different od anchors, ~18% SPS coverage."""
    cat = generate_catalog(seed=seed, regions=("eastus", "westeurope"))
    rng = np.random.default_rng(seed)
    out = []
    for o in cat:
        keep_sps = rng.random() < 0.179       # paper: 17.9% valid SPS
        out.append(o.__class__(**{
            **o.__dict__,
            "od_price": round(o.od_price * 1.07, 4),   # Azure od premium
            "t3": o.t3 if keep_sps else 0,             # invalid SPS -> unusable
        }))
    return out


def run():
    req = Request(pods=100, cpu_per_pod=2, mem_per_pod=2)
    results = {}
    for name, cat in (("aws", generate_catalog(seed=42)),
                      ("azure", azure_like_catalog(seed=42))):
        items = preprocess(cat, req)
        market = compile_market(items)
        pool, trace = bracketed_gss(items, req.pods, tolerance=0.01,
                                    market=market)
        grid = [i / 10 for i in range(11)]
        batch = solve_ilp_batch(items, req.pods, grid, market=market)
        curve = score_counts_batch(items, batch, req.pods,
                                   arrays=market.metric_arrays)
        peak = int(np.argmax(curve))
        results[name] = {
            "e_total": e_total(pool, req.pods),
            "candidates": len(items),
            "concave": bool(curve[peak] >= curve[0] - 1e-9
                            and curve[-1] < 0.05 * max(curve[peak], 1e-9)),
            "wall_s": trace.wall_seconds,
        }
    results["azure_over_aws"] = (results["azure"]["e_total"]
                                 / results["aws"]["e_total"])
    results["us_per_call"] = results["aws"]["wall_s"] * 1e6
    return results


def main():
    out = run()
    print(f"fig6b_cross_provider,{out['us_per_call']:.0f},"
          f"aws_candidates={out['aws']['candidates']};"
          f"azure_candidates={out['azure']['candidates']};"
          f"both_concave={out['aws']['concave'] and out['azure']['concave']};"
          f"azure/aws_E={out['azure_over_aws']:.3f}")
    return out


if __name__ == "__main__":
    main()
