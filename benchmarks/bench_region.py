"""RegionPlane benchmark: cross-region failover vs region pinning under a
correlated regional brownout storm (DESIGN.md §17).

Emits ``BENCH_region.json`` — FleetSim sweeps over a 3-region catalog
whose per-region price paths share a correlated shock factor
(``rho = 0.7``) while the home region walks through
:func:`repro.chaos.region_storm` (brownout → outage → partition):

  * ``hardened`` rides the §17 failover rung: the faulted region's rows
    are quarantined and demand is re-solved into the survivors with
    egress priced into the objective — the multi-region control plane;
  * ``region_pinned:<home>`` is the single-market strawman: all capacity
    in the home region, so every regional fault window is an outage;
  * **SLO perf-per-dollar** (the §16 backfill accounting, reused from
    :mod:`benchmarks.bench_chaos`): unserved demand is billed and
    credited at the catalog's cheapest on-demand rate, so losing the
    cluster costs what it actually costs;
  * ``headline.region_failover_vs_pinned_ratio`` — hardened over pinned
    on SLO perf-per-dollar — must meet ``TARGET_RATIO``;
  * before measuring, the bench re-proves the §9/§16/§17 contracts:
    determinism under the correlated storm (same seed ⇒ byte-identical
    trace; RNG-free replay; fleet ≡ standalone), **single-region
    inertness** (a K=1 RegionalCatalog scenario is byte-identical to the
    equivalent region-free scenario), and **identity-config inertness**
    (``hardened`` with a solver-inert RegionConfig and no region faults
    decides bit-identically to ``hardened`` without one).  A regional
    layer that moves any of those bits would invalidate the comparison,
    so violations raise.

Usage:
  python -m benchmarks.bench_region [--smoke] [--json PATH]

``make bench-region`` refreshes the checked-in BENCH_region.json.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import platform
import time
from typing import Dict, List, Optional

import numpy as np

from benchmarks.bench_chaos import od_backfill_rate, slo_metrics
from repro.chaos import fault_storm, region_storm
from repro.region import RegionConfig, region_pool_shares
from repro.sim.engine import ClusterSim
from repro.sim.fleet import run_fleet
from repro.sim.scenario import Scenario
from repro.sim.trace import loads_trace

#: acceptance bar (ISSUE 10): hardened-with-failover ≥ 1.3× region-pinned
#: SLO perf-per-dollar through the correlated regional brownout storm
TARGET_RATIO = 1.3

HOME = "us-east-1"
REGIONS = ("us-east-1", "us-west-2", "eu-west-1")
POLICIES = ("hardened", f"region_pinned:{HOME}")

_DENOM_FLOOR = 1e-9


def region_config(shock_seed: int = 11) -> RegionConfig:
    """The bench's 3-region market: correlated (rho 0.7) with real
    idiosyncratic volatility, data gravity toward the home region."""
    return RegionConfig(regions=REGIONS, rho=0.7, vol=0.25,
                        shock_seed=shock_seed, home_region=HOME,
                        egress_per_pod_hour=0.002)


def region_scenario(policy: str, *, storm: bool = True,
                    shock_seed: int = 11) -> Scenario:
    """48 h / 3 h-step regional storm scenario — same grid discipline as
    ``bench_chaos`` (every region_storm window edge on a tick boundary)."""
    return Scenario(
        name=f"region_{'storm' if storm else 'clean'}",
        duration_hours=48.0, step_hours=3.0, pods=160,
        demand_schedule=((12.0, 220), (24.0, 140)),
        interrupt_model="pressure", policy=policy,
        catalog_seed=7, max_offerings=200, market_seed=7, interrupt_seed=7,
        region=region_config(shock_seed),
        faults=region_storm(HOME) if storm else ())


def _strip_region_header(trace: str) -> str:
    """Normalize a trace's header for inertness comparisons: the scenario
    dict's ``region``/``name``/``policy`` fields are *declared config*,
    not behavior — every other byte must match on its own."""
    lines = trace.splitlines()
    head = json.loads(lines[0])
    head["scenario"]["region"] = None
    head["scenario"]["name"] = ""
    head["scenario"]["policy"] = ""
    lines[0] = json.dumps(head, sort_keys=True)
    return "\n".join(lines)


def _contract_checks() -> Dict[str, bool]:
    """Determinism + the two §17 inertness obligations."""
    sc = region_scenario("hardened")
    a = ClusterSim(sc, clock=lambda: 0.0).run()
    b = ClusterSim(sc, clock=lambda: 0.0).run()
    determinism = a.recorder.dumps() == b.recorder.dumps()
    replay = (ClusterSim.replay(loads_trace(a.recorder.dumps()))
              .run().recorder.dumps() == a.recorder.dumps())
    fleet = run_fleet(sc, [sc.interrupt_seed], record_traces=True,
                      clock=lambda: 0.0)[0]
    fleet_eq = fleet.recorder.dumps() == a.recorder.dumps()

    # single-region inertness: K=1 RegionalCatalog ≡ the region-free
    # scenario over the identical (restricted) catalog, byte-for-byte
    plain = Scenario(name="region_clean", duration_hours=24.0,
                     step_hours=3.0, pods=120, policy="kubepacs",
                     catalog_seed=7, max_offerings=200, market_seed=7,
                     interrupt_seed=7)
    k1 = dataclasses.replace(plain,
                             region=RegionConfig(regions=(HOME,)))
    cat = k1.build_catalog()
    rk1 = ClusterSim(k1, clock=lambda: 0.0).run()
    rpl = ClusterSim(plain, catalog=cat, clock=lambda: 0.0).run()
    single_inert = (_strip_region_header(rk1.recorder.dumps())
                    == _strip_region_header(rpl.recorder.dumps())
                    and rk1.total_egress == 0.0)

    # identity-config inertness: hardened + solver-inert RegionConfig +
    # a *non-region* storm ≡ hardened without a RegionConfig — the
    # failover rung must be bit-inert when no region faults are declared
    storm = fault_storm("combined")
    ident = dataclasses.replace(plain, policy="hardened", faults=storm,
                                region=RegionConfig(regions=REGIONS))
    bare = dataclasses.replace(plain, policy="hardened", faults=storm)
    rid = ClusterSim(ident, catalog=ident.build_catalog(),
                     clock=lambda: 0.0).run()
    rbare = ClusterSim(bare, catalog=ident.build_catalog(),
                       clock=lambda: 0.0).run()
    identity_inert = (_strip_region_header(rid.recorder.dumps())
                      == _strip_region_header(rbare.recorder.dumps()))

    return {"determinism_ok": determinism, "replay_ok": replay,
            "fleet_ok": fleet_eq, "single_region_inert": single_inert,
            "identity_config_inert": identity_inert}


def _mean(rows: List[Dict[str, float]], key: str) -> float:
    return float(np.mean([r[key] for r in rows]))


def _sweep(seeds: List[int], path_seeds: List[int], od_rate: float,
           od_perf: float) -> Dict[str, Dict]:
    """Both policies through every correlated market path × interrupt
    seed, byte-identical storm/market/interrupt streams across policies."""
    rows = {}
    for policy in POLICIES:
        per_seed: List[Dict[str, float]] = []
        ladder: Dict[str, int] = {}
        shares: Dict[str, int] = {}
        egress = 0.0
        t0 = time.perf_counter()
        for ps in path_seeds:
            sc = region_scenario(policy, shock_seed=ps)
            results = run_fleet(sc, seeds, clock=lambda: 0.0)
            for r in results:
                per_seed.append(slo_metrics(r, od_rate, od_perf))
                egress += r.total_egress
                for reg, n in region_pool_shares(r.pool).items():
                    shares[reg] = shares.get(reg, 0) + n
            for k, v in results[0].cache_stats.items():
                if k.startswith("chaos_region"):
                    ladder[k] = ladder.get(k, 0) + v
        wall = time.perf_counter() - t0
        agg = {k: round(_mean(per_seed, k), 4)
               for k in ("raw_perf_per_dollar", "slo_perf_per_dollar",
                         "decision_availability", "demand_coverage",
                         "deficit_pod_hours", "cost")}
        agg["wall_s"] = round(wall, 3)
        agg["total_egress"] = round(egress, 4)
        agg["final_pool_shares"] = shares
        agg["per_seed"] = per_seed
        if ladder:
            agg["failover_ladder"] = ladder
        rows[policy] = agg
    return rows


def run(smoke: bool = False, json_path: Optional[str] = None) -> dict:
    seeds = [7] if smoke else [3, 7, 11]
    path_seeds = [11] if smoke else [11, 23]

    checks = _contract_checks()
    if not all(checks.values()):
        raise AssertionError(
            f"region contracts violated: {checks} — determinism and the "
            "inertness obligations are preconditions for a meaningful "
            "failover-vs-pinned comparison")

    od_rate, od_perf = od_backfill_rate(
        region_scenario("kubepacs", storm=False))
    sweep = _sweep(seeds, path_seeds, od_rate, od_perf)

    hard = sweep["hardened"]
    pinned = sweep[f"region_pinned:{HOME}"]
    ratio = (hard["slo_perf_per_dollar"]
             / max(pinned["slo_perf_per_dollar"], _DENOM_FLOOR))
    out = {
        "benchmark": "bench_region",
        "python": platform.python_version(),
        "numpy": np.__version__,
        "machine": platform.machine(),
        "seeds": seeds,
        "path_seeds": path_seeds,
        "regions": list(REGIONS),
        "home_region": HOME,
        "od_backfill_rate_per_pod_hour": round(od_rate, 6),
        "od_backfill_perf_per_pod_hour": round(od_perf, 4),
        "target_ratio": TARGET_RATIO,
        "contracts": checks,
        "sweep": sweep,
        "headline": {
            "region_failover_vs_pinned_ratio": round(ratio, 3),
            "hardened_slo_perf_per_dollar": hard["slo_perf_per_dollar"],
            "pinned_slo_perf_per_dollar": pinned["slo_perf_per_dollar"],
            "hardened_demand_coverage": hard["demand_coverage"],
            "pinned_demand_coverage": pinned["demand_coverage"],
            "hardened_total_egress": hard["total_egress"],
            "meets_target": ratio >= TARGET_RATIO,
        },
    }
    if json_path:
        with open(json_path, "w") as f:
            json.dump(out, f, indent=2)
    return out


def gate_measurement(repeat: int = 1) -> dict:
    """The ``make perf-gate`` metrics: the failover ratio plus the §17
    hard contracts.  Numpy-engine deterministic (region policies solve
    inline through the same backend-bitwise stack), so one run suffices;
    ``repeat`` is accepted for signature parity."""
    checks = _contract_checks()
    od_rate, od_perf = od_backfill_rate(
        region_scenario("kubepacs", storm=False))
    rows = _sweep([7], [11], od_rate, od_perf)
    hard = rows["hardened"]
    pinned = rows[f"region_pinned:{HOME}"]
    ratio = (hard["slo_perf_per_dollar"]
             / max(pinned["slo_perf_per_dollar"], _DENOM_FLOOR))
    return {
        "region_failover_vs_pinned_ratio": round(ratio, 3),
        "determinism_ok": (checks["determinism_ok"] and checks["replay_ok"]
                           and checks["fleet_ok"]),
        "single_region_inert": checks["single_region_inert"],
        "identity_config_inert": checks["identity_config_inert"],
        "hardened_demand_coverage": hard["demand_coverage"],
    }


def main(argv: Optional[List[str]] = None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="one interrupt seed, one market path (CI)")
    ap.add_argument("--json", default="",
                    help="output record path (e.g. BENCH_region.json; "
                         "default: don't write)")
    args = ap.parse_args(argv if argv is not None else [])
    out = run(smoke=args.smoke, json_path=args.json or None)
    h = out["headline"]
    detail = (f"slo_ppd_ratio={h['region_failover_vs_pinned_ratio']}x"
              f";coverage={h['hardened_demand_coverage']}"
              f"vs{h['pinned_demand_coverage']}"
              f";egress=${h['hardened_total_egress']}"
              f";target>={out['target_ratio']}x:"
              f"{'met' if h['meets_target'] else 'MISSED'}")
    wall = out["sweep"]["hardened"]["wall_s"]
    print(f"bench_region,{round(wall * 1e6)},{detail}")
    return out


if __name__ == "__main__":
    import sys
    main(sys.argv[1:])
